//! Sharded residual-push: the Gauss–Southwell diffusion split into
//! per-shard bucket queues with *residual-fragment* exchange.
//!
//! The paper's premise is that synchronization phases are what stops
//! PageRank from scaling on real hardware; [`super::PushState`] removed
//! the sweep structure but kept a single queue. This module removes the
//! single queue: rows are split into contiguous shards by
//! [`Partitioner::balanced_nnz_lens`] over the *out*-row nonzeros (the
//! cost a push actually pays), and each [`PushShard`] runs the push
//! loop over its own rows with its own [`BucketQueue`].
//!
//! A push at `u` that hits an out-of-shard target does not touch the
//! peer's state; the mass lands in a per-peer **outbox** — an
//! accumulator keyed by the peer's rows, so repeated hits coalesce
//! instead of growing a message list. The representation adapts to the
//! shard count ([`OutboxPolicy`]): dense f64 arrays while the
//! O(shards·n) worst case is affordable, ordered sparse maps above
//! [`SPARSE_OUTBOX_SHARDS`] shards. Outboxes are exchanged as
//! [`ResidualFragment`]s: batches of `(node, mass)` pairs plus a
//! uniform term for dangling emissions. Residual mass is *additive and
//! conservative* — fragments can be deferred, reordered, or merged
//! without changing the fixed point, which is exactly why D-Iteration
//! (Hong et al.) and randomized distributed PageRank (Ishii–Tempo)
//! distribute so naturally, and what whole-rank fragments (the
//! `asynciter::threads` default) can never offer: a dropped rank
//! fragment loses information, a deferred residual fragment just waits.
//!
//! Two drivers share the shard mechanics:
//! * [`ShardedPush::solve`] — deterministic round-based superstep loop
//!   (drain every shard, deliver every outbox, repeat), the reference
//!   semantics and the property-test subject;
//! * [`crate::asynciter::threads::run_threaded_push`] — the same shards
//!   on real OS threads with bounded channels (fragments that meet a
//!   full channel are re-accumulated locally and retried — never lost).
//!
//! # Ownership and intra-epoch work stealing
//!
//! Rows have two coordinates (see [`crate::coordinator::OwnerMap`]):
//! a **home** — the shard whose contiguous block contains the row,
//! fixed between re-partitions — and an **owner** — the shard currently
//! holding its rank mass and queued residual. They coincide until a
//! steal: an idle shard adopts a slice of the hottest rows from a
//! loaded peer ([`ShardedPush::steal_rows`]; the threaded backend
//! negotiates the same transfer over its bounded channels). The stolen
//! row's `p`/`r`/epoch-stamp state moves into **overflow slots**
//! appended after the thief's home range, while *all uniform-mass
//! accounting stays home-based*: the victim's replicated `uni` scalar
//! keeps standing for `uni/n` on every home row, and any mass arriving
//! at the home shard for a lent row — a fragment entry, a uniform
//! flush — is forwarded to the owner through the same additive outbox
//! currency the shards already exchange. Forwarding is at most one hop
//! (only home-owned rows can be stolen, and an adopted row is never
//! re-stolen), deferral-tolerant, and conservative, so every invariant
//! below survives rows changing owners mid-solve.
//! [`ShardedPush::repatriate`] returns all adopted rows home and folds
//! the ownership overlay back to plain contiguous bounds — the epoch
//! boundaries ([`apply_batch`], [`rebalance`], [`gather_into`]) do this
//! first, so node arrivals and bounds re-cuts only ever see contiguous
//! ownership.
//!
//! [`apply_batch`]: ShardedPush::apply_batch
//! [`rebalance`]: ShardedPush::rebalance
//! [`gather_into`]: ShardedPush::gather_into
//!
//! # The conserved mass invariant
//!
//! The conserved quantity that makes all of this testable: with
//! `R = Σr + Σ_s uni_s·|B_s|/n + Σ_s pv_s·vshare_s/Σv + pending
//! outboxes`, the invariant `Σp + R/(1-α) = Σv` (`Σv = 1` on the
//! uniform path) holds after every push, exchange, flush, steal, and
//! repatriation (each push at mass `m` moves `m` into the estimate
//! and re-emits exactly `α·m`; transfers between shards move mass
//! without creating it). [`ShardedPush::mass`] computes it; the
//! property tests pin it to 1e-9. A personalized engine
//! ([`ShardedPush::new_personalized`]) carries `pv` — the replicated
//! pending-`v` scalar, `uni`'s twin weighed by per-shard `v`-mass
//! shares instead of row counts — through the exact same machinery.

use std::collections::HashMap;
use std::sync::Arc;

use super::delta::DeltaGraph;
use super::pers::Personalization;
use super::push::{BucketQueue, PushState};
use crate::coordinator::{OwnerMap, Partitioner};
use crate::obs::{EventKind, Sample, TraceCollector, MONITOR_TRACK};

/// One batch of residual mass in flight between shards.
///
/// `entries` are `(global node id, mass)` pairs addressed to the
/// receiving shard's rows; `uni` is uniform mass to be spread as
/// `uni/n` over each of the receiver's rows (the receiver's slice of a
/// dangling emission — every shard gets its own copy of the scalar, so
/// the copies jointly cover the whole graph). `pv` is the
/// personalization twin: pending mass to be spread as `pv·v_t/Σv` over
/// the receiver's home slice of the personalization support (always 0
/// on the uniform path).
#[derive(Debug, Clone)]
pub struct ResidualFragment {
    pub entries: Vec<(u32, f64)>,
    pub uni: f64,
    pub pv: f64,
}

/// One row mid-migration between shards: the full per-row solver state
/// a steal transfers. `touched` records whether the row had already
/// been counted in this epoch's touched-row accounting, so the count
/// moves with the row instead of double- or under-counting.
#[derive(Debug, Clone)]
pub(crate) struct StolenRow {
    pub(crate) node: u32,
    pub(crate) p: f64,
    pub(crate) r: f64,
    pub(crate) touched: bool,
}

/// A batch of rows whose ownership is being transferred from a victim
/// shard to a thief — the work-stealing counterpart of
/// [`ResidualFragment`]. Like residual fragments, grants are additive
/// state in flight: an undeliverable grant is restored to the victim
/// ([`PushShard::restore_grant`]) without losing a unit of mass.
#[derive(Debug, Clone)]
pub(crate) struct StealGrant {
    pub(crate) rows: Vec<StolenRow>,
}

/// Sentinel in the lent-row table: the row is still owned here.
const OWNED: u16 = u16::MAX;

/// Shard count above which [`OutboxPolicy::Auto`] picks the sparse
/// outbox representation. At this count and below, the dense
/// accumulators' O(shards·n) worst case stays within a small multiple
/// of the solver state itself; above it the quadratic-in-shards
/// footprint starts to dominate and the O(touched) maps win.
pub const SPARSE_OUTBOX_SHARDS: usize = 8;

/// Outbox representation policy for [`ShardedPush`] — how each shard
/// accumulates mass bound for a peer between exchanges.
///
/// Either representation reaches the same fixed point: an outbox is
/// additive residual mass in flight, and the choice only moves where
/// repeated hits coalesce (a dense slot vs a map entry). Each policy is
/// individually deterministic — the sparse maps drain in ascending node
/// order, so reruns are bit-identical — and the equivalence proptests
/// pin dense-vs-sparse solves to the same answer within the solve
/// tolerance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutboxPolicy {
    /// Dense accumulators up to [`SPARSE_OUTBOX_SHARDS`] shards, sparse
    /// maps above.
    #[default]
    Auto,
    /// Always the dense per-peer accumulators (O(shards·n) worst case).
    Dense,
    /// Always the sparse maps (O(touched entries), pay a map op per
    /// outbox hit).
    Sparse,
}

impl OutboxPolicy {
    /// Resolve the representation for a concrete shard count.
    fn sparse_for(self, shards: usize) -> bool {
        match self {
            OutboxPolicy::Auto => shards > SPARSE_OUTBOX_SHARDS,
            OutboxPolicy::Dense => false,
            OutboxPolicy::Sparse => true,
        }
    }
}

/// One per-peer outbox, in the representation the engine's
/// [`OutboxPolicy`] selected. Both variants keep the incremental
/// `acc_mass`/`acc_sum` tallies exact and both are drained whole by
/// `take_fragment`.
#[derive(Debug, Clone)]
enum Outbox {
    /// Lazily-allocated f64 accumulator over the peer's home rows plus
    /// a forward list for entries *outside* that range (rows the peer
    /// adopted from us mid-steal).
    Dense {
        /// Accumulator indexed by the peer's local rows; empty until
        /// first use (warm epochs rarely touch every peer, and eager
        /// allocation would cost O(shards·n) up front).
        acc: Vec<f64>,
        /// Positions possibly nonzero in `acc`. May hold duplicates
        /// (exact cancellation to 0.0 drops the membership marker);
        /// readers must tolerate zeros and repeats.
        dirty: Vec<u32>,
        /// `(global node, mass)` forwards for rows outside the peer's
        /// home range. Entries may repeat (the receiver's `add_r`
        /// coalesces); they count into the tallies per entry.
        fwd: Vec<(u32, f64)>,
    },
    /// Ordered map, global node id → accumulated mass. Home entries and
    /// steal forwards share the map; repeats coalesce at insert, and an
    /// entry cancelling to exactly 0.0 is removed (its tally
    /// contribution is zero, so dropping it is exact).
    Sparse(std::collections::BTreeMap<u32, f64>),
}

impl Outbox {
    fn new(sparse: bool) -> Outbox {
        if sparse {
            Outbox::Sparse(std::collections::BTreeMap::new())
        } else {
            Outbox::Dense { acc: Vec::new(), dirty: Vec::new(), fwd: Vec::new() }
        }
    }

    /// Nothing pending to take. For the dense variant an empty `dirty`
    /// implies an all-zero `acc` (every nonzero write pushes a marker),
    /// so this never needs the O(rows) sweep.
    fn is_clear(&self) -> bool {
        match self {
            Outbox::Dense { dirty, fwd, .. } => dirty.is_empty() && fwd.is_empty(),
            Outbox::Sparse(map) => map.is_empty(),
        }
    }
}

/// Outcome of one [`ShardedPush::solve`] call.
#[derive(Debug, Clone, Copy)]
pub struct ShardSolveStats {
    /// Pushes performed across all shards.
    pub pushes: u64,
    /// Drain/exchange supersteps.
    pub rounds: u64,
    /// Fragments delivered between shards.
    pub fragments: u64,
    /// Residual mass at exit (exact, re-tallied).
    pub residual: f64,
    pub converged: bool,
}

/// One shard: a contiguous row range with its own push state, queue,
/// and per-peer outboxes.
#[derive(Debug, Clone)]
pub struct PushShard {
    id: usize,
    pub(crate) lo: usize,
    pub(crate) hi: usize,
    /// Global node count (uniform terms divide by this, not by `bs`).
    pub(crate) n: usize,
    alpha: f64,
    pub(crate) part: Partitioner,
    /// Rank estimate over the local rows.
    pub(crate) p: Vec<f64>,
    /// Materialized residual over the local rows.
    pub(crate) r: Vec<f64>,
    /// Incrementally maintained Σ|r| (re-verified before convergence).
    pub(crate) r_l1: f64,
    /// Pending uniform residual, local-share semantics: stands for
    /// `uni/n` on each *local* row (peers hold their own copies).
    pub(crate) uni: f64,
    /// Pending personalization residual, local-share semantics: stands
    /// for `pv·v_t/Σv` on each *home* row `t` carrying personalization
    /// weight (peers hold their own copies; together the copies cover
    /// the support exactly, just as the `uni` copies cover the graph).
    /// Always 0 on the uniform path.
    pub(crate) pv: f64,
    /// Per-peer `Σ v_t` over each shard's home rows — how the
    /// replicated `pv` scalar is weighed, exactly like `|B_s|/n`
    /// weighs `uni`. All zeros on the uniform path.
    pub(crate) vshares: Vec<f64>,
    /// `(local index, weight)` flush targets of `pv`: the
    /// personalization entries homed in `[lo, hi)`. A lent row's flush
    /// share forwards to its owner through `add_r`, so v-mass
    /// accounting stays home-based across steals.
    vlocal: Vec<(u32, f64)>,
    /// `Σv` across the whole vector (1.0 on the uniform path, so the
    /// `pv`-share divisions are always safe).
    pub(crate) vtotal: f64,
    /// Route dangling emissions through `out_pv` instead of `out_uni`.
    dangling_to_v: bool,
    queue: BucketQueue,
    /// Head-tracking hook (see [`PushState`]'s twin): local rows whose
    /// `p + r` rises to `head_floor` inside `add_r` are appended to
    /// `head_hits`; `+INF` disables collection. `p + r` is invariant
    /// under a settle and the per-shard uniform share is constant
    /// across local rows, so every center movement that could promote
    /// a row into the head passes through `add_r` — a fragment apply,
    /// a uniform flush, a `pv` flush, and a delta injection all land
    /// here. (The `pv` share itself is *not* row-constant, but the
    /// tracker bounds untracked rows by the max share `pv⁺·vmax/Σv`,
    /// and its landing on a row goes through `add_r` too.)
    pub(crate) head_floor: f64,
    pub(crate) head_hits: Vec<u32>,
    /// Per-peer outboxes (`outbox[j]` accumulates mass bound for peer
    /// `j`), in the representation the engine's [`OutboxPolicy`]
    /// selected. `outbox[id]` stays clear: in-shard pushes apply
    /// directly.
    outbox: Vec<Outbox>,
    /// Which representation fresh outboxes take — kept per shard so
    /// bounds growth can re-materialize a peer's slot in kind.
    sparse_outbox: bool,
    /// Σ|acc| across all outboxes (incremental).
    pub(crate) acc_mass: f64,
    /// Per-peer pending uniform broadcast (dangling emissions waiting
    /// to ship; `out_uni[id]` is the self-share, absorbed locally).
    pub(crate) out_uni: Vec<f64>,
    /// Per-peer pending personalization broadcast — `out_uni`'s twin,
    /// fed by dangling emissions when the vector routes them through
    /// `v` (`out_pv[id]` is the self-share, absorbed locally).
    pub(crate) out_pv: Vec<f64>,
    pushes: u64,
    /// Signed Σp over the local rows (incremental — lets
    /// [`ShardedPush::mass`] stay O(shards) instead of O(n)).
    p_sum: f64,
    /// Signed Σr over the local rows (incremental).
    pub(crate) r_sum: f64,
    /// Signed Σacc over all outboxes (incremental).
    pub(crate) acc_sum: f64,
    /// Epoch stamp per local row + the shard's current epoch — the
    /// touched-node accounting that used to live only in the global
    /// [`PushState`], needed here once the state is epoch-resident.
    stamp: Vec<u64>,
    cur_stamp: u64,
    touched: usize,
    /// Per-home-row lent table (`OWNED` = still ours, otherwise the
    /// thief's shard id). Allocated lazily on the first steal and
    /// dropped when the last lent row returns. A lent row's local
    /// `p`/`r` slots read exactly zero — arriving mass is forwarded to
    /// the owner through the outbox instead of accumulating here.
    lent: Option<Vec<u16>>,
    lent_count: usize,
    /// Global node ids of adopted foreign rows, one per overflow slot:
    /// `adopted[i]` lives at local slot `bs + i` (after the home
    /// range) in `p`/`r`/`stamp`/the queue.
    pub(crate) adopted: Vec<u32>,
    /// Global node id → overflow slot index.
    adopted_slot: HashMap<u32, u32>,
}

impl PushShard {
    fn new(id: usize, part: &Partitioner, n: usize, alpha: f64, sparse: bool) -> PushShard {
        let s = part.p();
        let (lo, hi) = part.blocks()[id];
        let bs = hi - lo;
        PushShard {
            id,
            lo,
            hi,
            n,
            alpha,
            part: part.clone(),
            p: vec![0.0; bs],
            r: vec![0.0; bs],
            r_l1: 0.0,
            uni: 0.0,
            pv: 0.0,
            vshares: vec![0.0; s],
            vlocal: Vec::new(),
            vtotal: 1.0,
            dangling_to_v: false,
            queue: BucketQueue::new(bs),
            head_floor: f64::INFINITY,
            head_hits: Vec::new(),
            outbox: (0..s).map(|_| Outbox::new(sparse)).collect(),
            sparse_outbox: sparse,
            acc_mass: 0.0,
            out_uni: vec![0.0; s],
            out_pv: vec![0.0; s],
            pushes: 0,
            p_sum: 0.0,
            r_sum: 0.0,
            acc_sum: 0.0,
            stamp: vec![0; bs],
            cur_stamp: 0,
            touched: 0,
            lent: None,
            lent_count: 0,
            adopted: Vec::new(),
            adopted_slot: HashMap::new(),
        }
    }

    /// Home-range size (`hi - lo`); local slots `>= bs` are overflow
    /// slots holding adopted rows.
    #[inline]
    pub(crate) fn home_size(&self) -> usize {
        self.hi - self.lo
    }

    /// Global node id at local slot `k`.
    #[inline]
    fn global_of(&self, k: usize) -> usize {
        let bs = self.home_size();
        if k < bs {
            self.lo + k
        } else {
            self.adopted[k - bs] as usize
        }
    }

    /// Current owner of home slot `k`, if lent away.
    #[inline]
    pub(crate) fn lent_owner(&self, k: usize) -> Option<usize> {
        match &self.lent {
            Some(l) if l[k] != OWNED => Some(l[k] as usize),
            _ => None,
        }
    }

    /// Local slot of adopted global row `t`, if this shard adopted it.
    #[inline]
    pub(crate) fn adopted_slot_of(&self, t: usize) -> Option<usize> {
        self.adopted_slot
            .get(&(t as u32))
            .map(|&s| self.home_size() + s as usize)
    }

    /// Snapshot the dense solver state for a process-boundary `State`
    /// frame: `(p, r, uni, pv, pushes)` over the home rows. Socket-tier
    /// only — callers must have flushed the outboxes first and must not
    /// be stealing (lent/adopted rows have no wire representation), so
    /// the home slices are the whole state.
    pub(crate) fn export_dense(&self) -> (Vec<f64>, Vec<f64>, f64, f64, u64) {
        debug_assert!(
            self.lent_count == 0 && self.adopted.is_empty(),
            "dense state export during an active steal"
        );
        debug_assert!(
            self.acc_mass == 0.0 && self.out_uni.iter().all(|&u| u == 0.0),
            "dense state export with unflushed outboxes"
        );
        let bs = self.home_size();
        (self.p[..bs].to_vec(), self.r[..bs].to_vec(), self.uni, self.pv, self.pushes)
    }

    /// Overwrite the dense solver state from a `State` frame — the
    /// inverse of [`export_dense`](Self::export_dense). Re-derives the
    /// incremental sums and reseeds the bucket queue (the shared
    /// rebuild step after a wholesale state swap).
    pub(crate) fn import_dense(
        &mut self,
        p: Vec<f64>,
        r: Vec<f64>,
        uni: f64,
        pv: f64,
        pushes: u64,
    ) {
        assert_eq!(p.len(), self.home_size(), "State frame sized to different bounds");
        assert_eq!(r.len(), self.home_size(), "State frame sized to different bounds");
        debug_assert!(
            self.lent_count == 0 && self.adopted.is_empty(),
            "dense state import during an active steal"
        );
        self.p_sum = p.iter().sum();
        let (queue, l1) = BucketQueue::seeded_from(&r);
        self.queue = queue;
        self.r_l1 = l1;
        self.r_sum = r.iter().sum();
        self.p = p;
        self.r = r;
        self.uni = uni;
        self.pv = pv;
        self.pushes = pushes;
    }

    /// Queued-residual magnitude on HOME slots only — the part a steal
    /// can actually export ([`steal_out`](Self::steal_out) never
    /// re-grants adopted rows). The threaded steal-pressure board
    /// publishes this instead of the full `r_l1`, so a thief is never
    /// routed to a peer whose depth is all un-grantable adopted work.
    /// O(adopted); exact up to the incremental tally's drift (clamped
    /// at zero).
    pub(crate) fn stealable_r_l1(&self) -> f64 {
        let bs = self.home_size();
        let adopted: f64 = self.r[bs..].iter().map(|v| v.abs()).sum();
        (self.r_l1 - adopted).max(0.0)
    }

    /// Global row range `[lo, hi)`.
    pub fn rows(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Pushes performed by this shard so far.
    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    #[inline]
    fn touch(&mut self, k: usize) {
        if self.stamp[k] != self.cur_stamp {
            self.stamp[k] = self.cur_stamp;
            self.touched += 1;
        }
    }

    /// Add residual `w` at local slot `k`. For a home slot lent to a
    /// thief, the mass is forwarded into the outbox toward the owner
    /// instead — a lent slot's local `r` stays exactly zero, so the
    /// row's state is never split across two shards.
    #[inline]
    fn add_r(&mut self, k: usize, w: f64) {
        if w == 0.0 {
            return;
        }
        if k < self.home_size() {
            if let Some(thief) = self.lent_owner(k) {
                let t = self.lo + k;
                self.out_mass(thief, t, w);
                return;
            }
        }
        let old = self.r[k];
        let new = old + w;
        self.r_l1 += new.abs() - old.abs();
        self.r_sum += w;
        self.r[k] = new;
        if self.p[k] + new >= self.head_floor {
            self.head_hits.push(k as u32);
        }
        self.queue.update(k, new.abs());
        self.touch(k);
    }

    /// Accumulate outgoing mass for peer `j` at global node `t`. The
    /// dense representation picks the accumulator when `t` is homed at
    /// `j` and the forward list otherwise (a forward to a thief that
    /// adopted one of our rows, or a restore of such an entry); the
    /// sparse representation coalesces both through one ordered map.
    /// Either way `acc_mass` gains `|new|-|old|` of the coalesced slot
    /// and `acc_sum` gains `w`, so the incremental tallies stay exact.
    #[inline]
    fn out_mass(&mut self, j: usize, t: usize, w: f64) {
        debug_assert_ne!(j, self.id);
        if w == 0.0 {
            return;
        }
        let bounds = self.part.bounds();
        let (dmass, dsum) = match &mut self.outbox[j] {
            Outbox::Dense { acc, dirty, fwd } => {
                if t >= bounds[j] && t < bounds[j + 1] {
                    if acc.is_empty() {
                        acc.resize(bounds[j + 1] - bounds[j], 0.0);
                    }
                    let k = t - bounds[j];
                    let old = acc[k];
                    if old == 0.0 {
                        dirty.push(k as u32);
                    }
                    let new = old + w;
                    acc[k] = new;
                    (new.abs() - old.abs(), w)
                } else {
                    fwd.push((t as u32, w));
                    (w.abs(), w)
                }
            }
            Outbox::Sparse(map) => {
                let old = map.get(&(t as u32)).copied().unwrap_or(0.0);
                let new = old + w;
                if new == 0.0 {
                    map.remove(&(t as u32));
                } else {
                    map.insert(t as u32, new);
                }
                (new.abs() - old.abs(), w)
            }
        };
        self.acc_mass += dmass;
        self.acc_sum += dsum;
    }

    /// Spread the local pending uniform into the materialized residual.
    pub(crate) fn flush_uni(&mut self) {
        let add = self.uni / self.n as f64;
        self.uni = 0.0;
        if add == 0.0 {
            return;
        }
        for k in 0..self.hi - self.lo {
            self.add_r(k, add);
        }
    }

    /// Spread the local pending personalization scalar into the
    /// materialized residual — `O(local nnz(v))`. `pv` zeroes even on
    /// a shard homing no support: its slice of the scalar carries no
    /// mass, so dropping it is exact (and keeps the drained-queue exit
    /// check from spinning on a scalar that can never flush).
    pub(crate) fn flush_v(&mut self) {
        let m = self.pv;
        self.pv = 0.0;
        if m == 0.0 || self.vlocal.is_empty() {
            return;
        }
        let scale = m / self.vtotal;
        // the flush targets are stable while flushing; take the list so
        // add_r can borrow self mutably, then put it back
        let vlocal = std::mem::take(&mut self.vlocal);
        for &(k, w) in &vlocal {
            self.add_r(k as usize, scale * w);
        }
        self.vlocal = vlocal;
    }

    /// `Σ v_t` over this shard's home rows.
    #[inline]
    pub(crate) fn vshare(&self) -> f64 {
        self.vshares[self.id]
    }

    /// `v`-weight of home-local row `k` (0 outside the support). Binary
    /// search over the local support — meant for small per-check
    /// lookups (top-k centers), not the push hot path.
    pub(crate) fn vweight_local(&self, k: usize) -> f64 {
        match self.vlocal.binary_search_by_key(&(k as u32), |&(i, _)| i) {
            Ok(i) => self.vlocal[i].1,
            Err(_) => 0.0,
        }
    }

    /// Largest single `v` weight homed on this shard — bounds any one
    /// home row's `pv` share (the top-k rest-bound needs it).
    pub(crate) fn vmax_local(&self) -> f64 {
        self.vlocal.iter().map(|&(_, w)| w).fold(0.0, f64::max)
    }

    /// |pending| scalar mass attributable to this shard's home rows:
    /// the uniform slice `|uni|·|B|/n` plus the personalization slice
    /// `|pv|·vshare/Σv`.
    #[inline]
    pub(crate) fn pending_abs(&self) -> f64 {
        self.uni.abs() * (self.hi - self.lo) as f64 / self.n as f64
            + self.pv.abs() * self.vshare() / self.vtotal
    }

    /// Move the self-addressed uniform and personalization shares into
    /// the local pending scalars (peers get theirs via fragments; we
    /// skip the channel).
    pub(crate) fn absorb_self_uniform(&mut self) {
        let u = std::mem::replace(&mut self.out_uni[self.id], 0.0);
        self.uni += u;
        let q = std::mem::replace(&mut self.out_pv[self.id], 0.0);
        self.pv += q;
    }

    /// One push at local slot `k` (home or adopted): settle `r[k]`,
    /// re-emit `α·r[k]` through the out-links — locally when the target
    /// is owned here (home or adopted), into the peer outbox otherwise
    /// (addressed to the target's *home*; the home forwards if it lent
    /// the row away), into the per-peer uniform broadcast when `u`
    /// dangles.
    fn push_row(&mut self, g: &DeltaGraph, k: usize) {
        let m = self.r[k];
        if m == 0.0 {
            return;
        }
        self.r_l1 -= m.abs();
        self.r_sum -= m;
        self.r[k] = 0.0;
        self.p[k] += m;
        self.p_sum += m;
        self.touch(k);
        let u = self.global_of(k);
        let d = g.outdeg(u);
        if d == 0 {
            let q = self.alpha * m;
            if self.dangling_to_v {
                for j in 0..self.out_pv.len() {
                    self.out_pv[j] += q;
                }
            } else {
                for j in 0..self.out_uni.len() {
                    self.out_uni[j] += q;
                }
            }
        } else {
            let w = self.alpha * m / d as f64;
            for &t in g.out(u) {
                let t = t as usize;
                if (self.lo..self.hi).contains(&t) {
                    self.add_r(t - self.lo, w);
                } else if let Some(ks) = self.adopted_slot_of(t) {
                    self.add_r(ks, w);
                } else {
                    let j = self.part.owner_of(t);
                    self.out_mass(j, t, w);
                }
            }
        }
        self.pushes += 1;
    }

    /// Drain the local queue: push hottest-first until the local
    /// residual drops below `target` or `budget` pushes are spent.
    /// Returns the pushes performed.
    pub(crate) fn drain(&mut self, g: &DeltaGraph, target: f64, budget: u64) -> u64 {
        let mut spent = 0u64;
        while spent < budget {
            let pending = self.pending_abs();
            if self.r_l1 + pending < target {
                break;
            }
            // spread the pending scalars when they dominate what is
            // materialized (same policy as PushState::solve)
            if pending >= self.r_l1.max(0.5 * target) {
                self.flush_uni();
                self.flush_v();
                continue;
            }
            match self.queue.pop() {
                Some(k) => {
                    self.push_row(g, k);
                    spent += 1;
                }
                None => {
                    if self.uni != 0.0 || self.pv != 0.0 {
                        self.flush_uni();
                        self.flush_v();
                    } else {
                        // queue drained: every r is exactly zero, the
                        // tally only holds accumulated drift
                        self.recompute_r_l1();
                        break;
                    }
                }
            }
        }
        spent
    }

    /// Exact recomputation of the incremental Σ|r| / Σr tallies (clears
    /// float-accumulation drift; the signed and rank sums re-tally in
    /// the same pass so `mass` stays honest too).
    pub(crate) fn recompute_r_l1(&mut self) {
        let (mut l1, mut s) = (0.0f64, 0.0f64);
        for &v in &self.r {
            l1 += v.abs();
            s += v;
        }
        self.r_l1 = l1;
        self.r_sum = s;
        self.p_sum = self.p.iter().sum();
    }

    /// Take everything pending for peer `j` as one fragment (`None`
    /// when nothing is pending). The outbox is left empty; restoring a
    /// rejected fragment re-accumulates it.
    pub(crate) fn take_fragment(&mut self, j: usize) -> Option<ResidualFragment> {
        debug_assert_ne!(j, self.id, "self mass is absorbed, not shipped");
        let uni = std::mem::replace(&mut self.out_uni[j], 0.0);
        let pv = std::mem::replace(&mut self.out_pv[j], 0.0);
        if self.outbox[j].is_clear() && uni == 0.0 && pv == 0.0 {
            return None;
        }
        let base = self.part.bounds()[j];
        let mut entries;
        let (mut taken_mass, mut taken_sum) = (0.0f64, 0.0f64);
        match &mut self.outbox[j] {
            Outbox::Dense { acc, dirty, fwd } => {
                entries = Vec::with_capacity(dirty.len() + fwd.len());
                for idx in 0..dirty.len() {
                    let k = dirty[idx] as usize;
                    let w = acc[k];
                    if w != 0.0 {
                        entries.push(((base + k) as u32, w));
                        taken_mass += w.abs();
                        taken_sum += w;
                        acc[k] = 0.0;
                    }
                }
                dirty.clear();
                for (t, w) in fwd.drain(..) {
                    entries.push((t, w));
                    taken_mass += w.abs();
                    taken_sum += w;
                }
            }
            Outbox::Sparse(map) => {
                // BTreeMap iterates in ascending node order, so sparse
                // drains are as deterministic as the dense dirty walk
                let map = std::mem::take(map);
                entries = Vec::with_capacity(map.len());
                for (t, w) in map {
                    entries.push((t, w));
                    taken_mass += w.abs();
                    taken_sum += w;
                }
            }
        }
        self.acc_mass -= taken_mass;
        self.acc_sum -= taken_sum;
        Some(ResidualFragment { entries, uni, pv })
    }

    /// Re-accumulate a fragment that could not be delivered (bounded
    /// channel full). Residual mass is additive, so deferral is
    /// lossless — the next `take_fragment` ships the merged batch.
    pub(crate) fn restore_fragment(&mut self, j: usize, frag: ResidualFragment) {
        self.out_uni[j] += frag.uni;
        self.out_pv[j] += frag.pv;
        for (t, w) in frag.entries {
            self.out_mass(j, t as usize, w);
        }
    }

    /// Apply a fragment addressed to this shard: entries land on home
    /// rows (forwarded to the owner if lent away) or on adopted rows'
    /// overflow slots.
    pub(crate) fn apply_fragment(&mut self, frag: &ResidualFragment) {
        for &(t, w) in &frag.entries {
            let t = t as usize;
            if (self.lo..self.hi).contains(&t) {
                self.add_r(t - self.lo, w);
            } else if let Some(ks) = self.adopted_slot_of(t) {
                self.add_r(ks, w);
            } else {
                debug_assert!(
                    false,
                    "fragment node {t} neither homed in [{}, {}) nor adopted",
                    self.lo,
                    self.hi
                );
                // release builds: never lose mass — park it toward the
                // row's home shard instead
                self.out_mass(self.part.owner_of(t), t, w);
            }
        }
        self.uni += frag.uni;
        self.pv += frag.pv;
    }

    /// Victim side of a steal: pop up to `batch` of the **hottest**
    /// queued home rows and package their full state as a grant for
    /// `thief`. The rows are marked lent — their local slots zero out
    /// and arriving mass forwards — and the epoch's touched-row credit
    /// travels with them. Adopted rows are never re-stolen (one-hop
    /// ownership keeps forwarding bounded); they are re-queued
    /// untouched. Returns `None` when nothing stealable is queued.
    pub(crate) fn steal_out(&mut self, thief: usize, batch: usize) -> Option<StealGrant> {
        debug_assert_ne!(thief, self.id, "cannot steal from yourself");
        let bs = self.home_size();
        let mut rows = Vec::new();
        let mut requeue = Vec::new();
        while rows.len() < batch {
            let Some(k) = self.queue.pop() else { break };
            if k >= bs {
                requeue.push(k);
                continue;
            }
            let m = self.r[k];
            self.r_l1 -= m.abs();
            self.r_sum -= m;
            self.r[k] = 0.0;
            let pv = self.p[k];
            self.p_sum -= pv;
            self.p[k] = 0.0;
            let touched = self.cur_stamp > 0 && self.stamp[k] == self.cur_stamp;
            if touched {
                self.touched -= 1;
                self.stamp[k] = self.cur_stamp.wrapping_sub(1);
            }
            let l = self.lent.get_or_insert_with(|| vec![OWNED; bs]);
            debug_assert_eq!(l[k], OWNED);
            l[k] = thief as u16;
            self.lent_count += 1;
            rows.push(StolenRow { node: (self.lo + k) as u32, p: pv, r: m, touched });
        }
        for k in requeue {
            self.queue.update(k, self.r[k].abs());
        }
        if rows.is_empty() {
            None
        } else {
            Some(StealGrant { rows })
        }
    }

    /// Thief side of a steal: append the granted rows as overflow slots
    /// and queue their residual. The caller updates the owner map (or,
    /// on the threaded path, reconciles it after the run).
    pub(crate) fn adopt_rows(&mut self, grant: StealGrant) -> usize {
        let bs = self.home_size();
        let count = grant.rows.len();
        for row in grant.rows {
            let t = row.node as usize;
            debug_assert!(
                !(self.lo..self.hi).contains(&t),
                "cannot adopt a row homed in this shard"
            );
            debug_assert!(!self.adopted_slot.contains_key(&row.node), "double adoption");
            let slot = self.adopted.len();
            self.adopted.push(row.node);
            self.adopted_slot.insert(row.node, slot as u32);
            let k = bs + slot;
            self.p.push(row.p);
            self.p_sum += row.p;
            self.r.push(row.r);
            self.r_l1 += row.r.abs();
            self.r_sum += row.r;
            // preserve the epoch stamp across the move (adoption is a
            // representation change, not new work) — an untouched row
            // must not read as touched, so park its stamp off-epoch
            self.stamp.push(if row.touched {
                self.cur_stamp
            } else {
                self.cur_stamp.wrapping_sub(1)
            });
            if row.touched {
                self.touched += 1;
            }
            self.queue.grow(k + 1);
            self.queue.update(k, row.r.abs());
            if self.p[k] + self.r[k] >= self.head_floor {
                self.head_hits.push(k as u32);
            }
        }
        count
    }

    /// Undo a grant that could not be delivered (bounded channel full):
    /// the victim re-owns the rows with their exact state. Must run
    /// before any further mass arrives for them (the worker loop calls
    /// it immediately on the failed send, while it still holds the
    /// shard exclusively).
    pub(crate) fn restore_grant(&mut self, grant: StealGrant) {
        for row in grant.rows {
            let k = row.node as usize - self.lo;
            debug_assert!(self.lent_owner(k).is_some(), "restoring a row that was not lent");
            debug_assert_eq!(self.r[k], 0.0, "mass leaked into a lent slot");
            if let Some(l) = self.lent.as_mut() {
                l[k] = OWNED;
            }
            self.lent_count -= 1;
            self.p[k] = row.p;
            self.p_sum += row.p;
            self.r[k] = row.r;
            self.r_l1 += row.r.abs();
            self.r_sum += row.r;
            if row.touched {
                self.touch(k);
            }
            self.queue.update(k, row.r.abs());
            if self.p[k] + self.r[k] >= self.head_floor {
                self.head_hits.push(k as u32);
            }
        }
        if self.lent_count == 0 {
            self.lent = None;
        }
    }

    /// Move a fraction of the biggest-rank home row's mass back into
    /// its residual, conserving the global invariant
    /// (`Δp = -dp`, `Δr = +dp·(1-α)`, so `Σp + Σr/(1-α)` is
    /// unchanged). Returns the residual injected (0 when the shard
    /// holds no positive rank). Termination-test support: it plants
    /// residual in exactly ONE shard — something real churn cannot do,
    /// since a column swap scatters deltas to arbitrary out-neighbors
    /// — which is what makes the stalled-worker premature-stop
    /// scenarios deterministic.
    pub(crate) fn unpush(&mut self, frac: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&frac));
        let bs = self.home_size();
        let mut k_best = None;
        for k in 0..bs {
            if self.lent_owner(k).is_some() || self.p[k] <= 0.0 {
                continue;
            }
            if k_best.map_or(true, |b: usize| self.p[k] > self.p[b]) {
                k_best = Some(k);
            }
        }
        let Some(k) = k_best else { return 0.0 };
        let dp = self.p[k] * frac;
        if dp <= 0.0 {
            return 0.0;
        }
        self.p[k] -= dp;
        self.p_sum -= dp;
        let dr = dp * (1.0 - self.alpha);
        self.add_r(k, dr);
        dr
    }

    /// Release every adopted row for repatriation, truncating the
    /// overflow slots. The queue is rebuilt from the remaining home
    /// rows (stale bucket entries may still reference the truncated
    /// slots), which also clears accumulated `r_l1` drift.
    fn release_adopted(&mut self) -> Vec<StolenRow> {
        let bs = self.home_size();
        let mut rows = Vec::with_capacity(self.adopted.len());
        for slot in 0..self.adopted.len() {
            let k = bs + slot;
            let m = self.r[k];
            self.r_sum -= m;
            let pv = self.p[k];
            self.p_sum -= pv;
            let touched = self.cur_stamp > 0 && self.stamp[k] == self.cur_stamp;
            if touched {
                self.touched -= 1;
            }
            rows.push(StolenRow { node: self.adopted[slot], p: pv, r: m, touched });
        }
        self.adopted.clear();
        self.adopted_slot.clear();
        self.p.truncate(bs);
        self.r.truncate(bs);
        self.stamp.truncate(bs);
        // pending hits may reference the truncated slots; the caller
        // bumps the head generation, so trackers rescan anyway
        self.head_hits.clear();
        let (queue, l1) = BucketQueue::seeded_from(&self.r);
        self.queue = queue;
        self.r_l1 = l1;
        rows
    }

    /// Conservative |residual| attributable to this shard: local
    /// materialized + local uniform share + everything parked in the
    /// outboxes (entries at full weight, uniforms at the receiver's
    /// share).
    pub(crate) fn residual_estimate(&self) -> f64 {
        let nf = self.n as f64;
        let mut est = self.r_l1 + self.pending_abs() + self.acc_mass;
        for (j, u) in self.out_uni.iter().enumerate() {
            let rows = self.part.bounds()[j + 1] - self.part.bounds()[j];
            est += u.abs() * rows as f64 / nf;
        }
        for (j, q) in self.out_pv.iter().enumerate() {
            est += q.abs() * self.vshares[j] / self.vtotal;
        }
        est
    }

    /// Signed residual total (for the mass-conservation invariant),
    /// O(shards): the dense Σr / Σacc sweeps this used to pay per call
    /// are carried incrementally (`r_sum`, `acc_sum`) and verified
    /// against [`signed_residual_dense`](Self::signed_residual_dense)
    /// in debug builds.
    fn signed_residual(&self) -> f64 {
        let nf = self.n as f64;
        let mut s = self.r_sum + self.acc_sum;
        s += self.uni * (self.hi - self.lo) as f64 / nf;
        s += self.pv * self.vshare() / self.vtotal;
        for (j, u) in self.out_uni.iter().enumerate() {
            let rows = self.part.bounds()[j + 1] - self.part.bounds()[j];
            s += u * rows as f64 / nf;
        }
        for (j, q) in self.out_pv.iter().enumerate() {
            s += q * self.vshares[j] / self.vtotal;
        }
        debug_assert!(
            {
                let dense = self.signed_residual_dense();
                (s - dense).abs() <= 1e-7 * (1.0 + dense.abs())
            },
            "incremental signed residual drifted from the dense tally"
        );
        s
    }

    /// Dense recomputation of the signed residual — the exact fallback
    /// behind the incremental accumulators. Sums the accumulators
    /// directly rather than walking `dirty`: the lists may hold
    /// duplicate indices (a slot that cancelled to exactly 0.0 and was
    /// re-dirtied loses its membership marker), which is harmless for
    /// `take_fragment` (zero entries are skipped, duplicates read 0.0
    /// after the first) but would double-count here.
    fn signed_residual_dense(&self) -> f64 {
        let nf = self.n as f64;
        let mut s: f64 = self.r.iter().sum();
        s += self.uni * (self.hi - self.lo) as f64 / nf;
        s += self.pv * self.vshare() / self.vtotal;
        for ob in &self.outbox {
            match ob {
                Outbox::Dense { acc, fwd, .. } => {
                    for &w in acc {
                        s += w;
                    }
                    for &(_, w) in fwd {
                        s += w;
                    }
                }
                Outbox::Sparse(map) => {
                    for &w in map.values() {
                        s += w;
                    }
                }
            }
        }
        for (j, u) in self.out_uni.iter().enumerate() {
            let rows = self.part.bounds()[j + 1] - self.part.bounds()[j];
            s += u * rows as f64 / nf;
        }
        for (j, q) in self.out_pv.iter().enumerate() {
            s += q * self.vshares[j] / self.vtotal;
        }
        s
    }

    /// Re-tally the outbox accumulators exactly (drift fallback for
    /// `acc_mass` / `acc_sum`). Dense forward entries count per entry,
    /// matching the incremental bookkeeping (duplicates are not
    /// coalesced until delivery); sparse maps already coalesce, so
    /// their values count once each.
    fn recompute_acc_sums(&mut self) {
        let (mut mass, mut sum) = (0.0f64, 0.0f64);
        for ob in &self.outbox {
            match ob {
                Outbox::Dense { acc, fwd, .. } => {
                    for &w in acc {
                        mass += w.abs();
                        sum += w;
                    }
                    for &(_, w) in fwd {
                        mass += w.abs();
                        sum += w;
                    }
                }
                Outbox::Sparse(map) => {
                    for &w in map.values() {
                        mass += w.abs();
                        sum += w;
                    }
                }
            }
        }
        self.acc_mass = mass;
        self.acc_sum = sum;
    }
}

/// The sharded push solver: a [`PushState`] split into per-shard bucket
/// queues over a balanced-nnz partition, with residual-fragment
/// exchange between shards.
///
/// Load balance has two time scales and two tools that compose:
/// [`rebalance`](Self::rebalance) re-cuts the contiguous home bounds
/// *between* epochs when churn durably skews the nnz distribution,
/// while [`steal_rows`](Self::steal_rows) (and the threaded steal
/// protocol in [`run_threaded_push`]) moves ownership of individual
/// hot rows *within* an epoch when the residual — the actual remaining
/// work — piles onto one shard. Steals ride the ownership overlay
/// ([`owner_map`](Self::owner_map)); every epoch-boundary operation
/// folds the overlay back ([`repatriate`](Self::repatriate)), so the
/// two mechanisms never see each other's bookkeeping. The conserved
/// mass `Σp + R/(1−α) = Σv` ([`mass`](Self::mass)) holds across both.
///
/// [`run_threaded_push`]: crate::asynciter::threads::run_threaded_push
#[derive(Debug, Clone)]
pub struct ShardedPush {
    alpha: f64,
    n: usize,
    /// Personalization vector (`None` = the uniform teleport `e/n`).
    /// Mirrored into every shard's `vshares`/`vlocal` views; the
    /// conserved mass becomes `Σp + R/(1−α) = Σv`.
    pers: Option<Arc<Personalization>>,
    part: Partitioner,
    /// Row ownership on top of the home partition — contiguous until
    /// intra-epoch work stealing moves rows; folded back by
    /// [`repatriate`](Self::repatriate).
    owners: OwnerMap,
    /// Pushes each shard may spend between exchanges (per round).
    pub round_pushes: u64,
    pub(crate) shards: Vec<PushShard>,
    /// Per-peer outbox representation policy (see [`OutboxPolicy`]);
    /// resolved against the live shard count whenever shards are
    /// (re)built.
    outbox_policy: OutboxPolicy,
    /// The shard count the caller asked for — [`rebalance`] re-targets
    /// this even when the initial partition clamped it to the row count.
    ///
    /// [`rebalance`]: Self::rebalance
    requested_shards: usize,
    /// Pushes performed by shard generations retired by `rebalance`.
    carried_pushes: u64,
    /// Lifetime rows adopted across all steals (deterministic
    /// [`steal_rows`](Self::steal_rows) and threaded grants).
    stolen_rows: u64,
    /// Lifetime steal grants delivered.
    steal_grants: u64,
    /// Epoch stamp mirrored into every shard by [`begin_epoch`]
    /// (the shards carry their own copy so the touched accounting works
    /// inside `run_threaded_push` workers).
    ///
    /// [`begin_epoch`]: Self::begin_epoch
    cur_stamp: u64,
    /// Bumped whenever row state moves without passing through `add_r`
    /// (bounds migration, node arrivals, a threaded run that consumed
    /// the shards' `head_hits`) — tells an attached
    /// [`TopKTracker`](super::TopKTracker) to rebuild its per-shard
    /// candidate pools instead of trusting the hit stream.
    head_gen: u64,
    /// Observability sink ([`crate::obs`]): when attached, the
    /// deterministic drivers (`solve`, `exchange`, `apply_batch`,
    /// `steal_rows`, `repatriate`) record typed events and
    /// per-superstep residual samples into it, and
    /// [`run_threaded_push`] picks it up when its options carry no
    /// explicit collector. `None` (the default) records nothing.
    trace: Option<Arc<TraceCollector>>,
}

impl ShardedPush {
    fn build(
        g: &DeltaGraph,
        alpha: f64,
        shards: usize,
        pers: Option<Arc<Personalization>>,
    ) -> ShardedPush {
        assert!(g.n() > 0, "empty graph");
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        assert!(shards >= 1, "need at least one shard");
        let requested = shards;
        let lens: Vec<usize> = (0..g.n()).map(|u| g.outdeg(u)).collect();
        let part = Partitioner::balanced_nnz_lens(&lens, shards);
        let n = g.n();
        if let Some(p) = &pers {
            assert!(
                (p.max_node() as usize) < n,
                "personalization entry {} out of bounds for n={n}",
                p.max_node()
            );
        }
        let outbox_policy = OutboxPolicy::Auto;
        let sparse = outbox_policy.sparse_for(part.p());
        let shards: Vec<PushShard> =
            (0..part.p()).map(|id| PushShard::new(id, &part, n, alpha, sparse)).collect();
        let mut sp = ShardedPush {
            alpha,
            n,
            pers,
            owners: OwnerMap::contiguous(part.clone()),
            part,
            round_pushes: 4096,
            shards,
            outbox_policy,
            requested_shards: requested,
            carried_pushes: 0,
            stolen_rows: 0,
            steal_grants: 0,
            cur_stamp: 0,
            head_gen: super::next_head_gen(),
            trace: None,
        };
        sp.configure_pers();
        sp
    }

    /// (Re)derive every shard's view of the personalization vector —
    /// per-peer `v`-mass shares, local flush targets, total, dangling
    /// policy — from the current home bounds. Idempotent; called after
    /// every bounds change (`build`, `grow_to`, `adopt_partition`).
    /// Leaves the pending `pv` scalars untouched.
    fn configure_pers(&mut self) {
        let Some(p) = &self.pers else { return };
        let vshares: Vec<f64> =
            self.part.blocks().iter().map(|&(lo, hi)| p.share_of_range(lo, hi)).collect();
        for sh in self.shards.iter_mut() {
            sh.vshares = vshares.clone();
            sh.vlocal = p.entries_in_range(sh.lo, sh.hi);
            sh.vtotal = p.total();
            sh.dangling_to_v = p.dangling_to_v();
        }
    }

    /// Cold state: `p = 0` everywhere and the full teleport mass
    /// `(1-α)` pending uniformly (each shard carries its own copy of
    /// the scalar — together they cover the graph exactly).
    pub fn new(g: &DeltaGraph, alpha: f64, shards: usize) -> ShardedPush {
        let mut sp = ShardedPush::build(g, alpha, shards, None);
        for sh in sp.shards.iter_mut() {
            sh.uni = 1.0 - alpha;
        }
        sp
    }

    /// Cold personalized state: `p = 0`, the sparse right-hand side
    /// `(1−α)·v` materialized directly into the owning shards'
    /// residuals (nothing pending — mirrors
    /// [`PushState::new_personalized`]).
    pub fn new_personalized(
        g: &DeltaGraph,
        alpha: f64,
        shards: usize,
        pers: Arc<Personalization>,
    ) -> ShardedPush {
        let mut sp = ShardedPush::build(g, alpha, shards, Some(pers));
        for sh in sp.shards.iter_mut() {
            let targets = std::mem::take(&mut sh.vlocal);
            for &(k, w) in &targets {
                sh.add_r(k as usize, (1.0 - alpha) * w);
            }
            sh.vlocal = targets;
        }
        sp
    }

    /// The personalization vector this engine solves against (`None` =
    /// uniform teleport).
    pub fn personalization(&self) -> Option<&Arc<Personalization>> {
        self.pers.as_ref()
    }

    /// `Σv` — what [`mass`](Self::mass) conserves (1 on the uniform
    /// path).
    pub fn target_mass(&self) -> f64 {
        self.pers.as_ref().map_or(1.0, |p| p.total())
    }

    /// Scatter a (possibly warm) [`PushState`] into shards: rank and
    /// residual slices move to their owners, the pending scalars (`rd`
    /// uniform, `rv` personalization) are replicated with local-share
    /// semantics, and the personalization vector rides along. `state`
    /// must be sized to `g` — apply deltas on the global state *before*
    /// scattering.
    pub fn from_state(state: &PushState, g: &DeltaGraph, shards: usize) -> ShardedPush {
        assert_eq!(state.n(), g.n(), "state sized to a different graph");
        let mut sp =
            ShardedPush::build(g, state.alpha(), shards, state.personalization().cloned());
        let ranks = state.ranks();
        let resid = state.residual();
        let rd = state.pending_uniform();
        let rv = state.pending_v();
        for sh in sp.shards.iter_mut() {
            for k in 0..sh.hi - sh.lo {
                sh.p[k] = ranks[sh.lo + k];
                let v = resid[sh.lo + k];
                sh.r[k] = v;
                sh.r_l1 += v.abs();
                sh.r_sum += v;
                sh.p_sum += sh.p[k];
                sh.queue.update(k, v.abs());
            }
            sh.uni = rd;
            sh.pv = rv;
        }
        sp
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-peer outbox representation policy in effect.
    pub fn outbox_policy(&self) -> OutboxPolicy {
        self.outbox_policy
    }

    /// Swap the per-peer outbox representation (see [`OutboxPolicy`]).
    /// Requires settled outboxes — call right after construction,
    /// between epochs, or after an [`exchange`](Self::exchange); the
    /// swap is a pure representation change, so nothing in flight can
    /// be dropped and the conserved mass is untouched. Panics if any
    /// outbox still holds undelivered mass.
    pub fn set_outbox_policy(&mut self, policy: OutboxPolicy) {
        assert!(
            self.shards
                .iter()
                .all(|sh| sh.acc_mass == 0.0 && sh.outbox.iter().all(Outbox::is_clear)),
            "outbox policy change with undelivered outbox mass (exchange first)"
        );
        self.outbox_policy = policy;
        let sparse = policy.sparse_for(self.shards.len());
        for sh in self.shards.iter_mut() {
            sh.sparse_outbox = sparse;
            for ob in sh.outbox.iter_mut() {
                *ob = Outbox::new(sparse);
            }
        }
    }

    /// The balanced-nnz partition in use (home bounds — see
    /// [`owner_map`](Self::owner_map) for the ownership overlay).
    pub fn partitioner(&self) -> &Partitioner {
        &self.part
    }

    /// Current row ownership: the home partition plus any intra-epoch
    /// steal displacements.
    pub fn owner_map(&self) -> &OwnerMap {
        &self.owners
    }

    /// Lifetime steal counters `(rows adopted, grants delivered)` —
    /// the per-epoch `stolen_rows` / `steal_grants` columns are deltas
    /// of these.
    pub fn steal_totals(&self) -> (u64, u64) {
        (self.stolen_rows, self.steal_grants)
    }

    /// Attach an observability collector ([`crate::obs`]): from here on
    /// the deterministic drivers record typed events (shard `i` →
    /// track `i`, epoch-level events → the monitor track) and
    /// per-superstep residual samples, and threaded runs over this
    /// state inherit the collector unless their options carry one.
    pub fn attach_trace(&mut self, tr: Arc<TraceCollector>) {
        self.trace = Some(tr);
    }

    /// Detach the collector (returns it so callers can export).
    pub fn detach_trace(&mut self) -> Option<Arc<TraceCollector>> {
        self.trace.take()
    }

    /// The attached collector, if any (cloned handle).
    pub fn trace_handle(&self) -> Option<Arc<TraceCollector>> {
        self.trace.clone()
    }

    /// Pushes across all shards so far (shard generations retired by
    /// [`rebalance`](Self::rebalance) included).
    pub fn total_pushes(&self) -> u64 {
        self.carried_pushes + self.shards.iter().map(|sh| sh.pushes).sum::<u64>()
    }

    /// Start a new epoch's touched-node accounting (mirrors
    /// [`PushState::begin_epoch`]; the resident epoch driver calls this
    /// before injecting a churn batch).
    pub fn begin_epoch(&mut self) {
        self.cur_stamp += 1;
        for sh in self.shards.iter_mut() {
            sh.cur_stamp = self.cur_stamp;
            sh.touched = 0;
        }
    }

    /// Distinct rows whose state changed since [`begin_epoch`]
    /// (delta injection, pushes, and received fragments included).
    ///
    /// [`begin_epoch`]: Self::begin_epoch
    pub fn touched(&self) -> usize {
        self.shards.iter().map(|sh| sh.touched).sum()
    }

    /// Candidate-pool staleness stamp for attached top-k trackers (see
    /// the field doc).
    pub(crate) fn head_gen(&self) -> u64 {
        self.head_gen
    }

    /// Mark every attached tracker's candidate pools stale (state moved
    /// without `add_r`, or a threaded run drained the hit lists).
    pub(crate) fn bump_head_gen(&mut self) {
        self.head_gen = super::next_head_gen();
    }

    /// Detach head tracking entirely: disarm every shard's entry floor,
    /// drop pending hits, and invalidate attached trackers. The three
    /// steps belong together — disarming without the gen bump would
    /// starve a tracker of hits; bumping without disarming would leave
    /// floors armed, growing the hit lists unboundedly under later
    /// untracked solves.
    pub(crate) fn detach_head_tracking(&mut self) {
        self.bump_head_gen();
        for sh in self.shards.iter_mut() {
            sh.head_floor = f64::INFINITY;
            sh.head_hits.clear();
        }
    }

    /// Rank estimate at global row `u` (reads the owning shard — home
    /// slot or, for a stolen row, the thief's overflow slot).
    pub fn rank_at(&self, u: usize) -> f64 {
        let j = self.owners.owner_of(u);
        let sh = &self.shards[j];
        if (sh.lo..sh.hi).contains(&u) {
            sh.p[u - sh.lo]
        } else {
            let ks = sh
                .adopted_slot_of(u)
                .expect("owner map points at a shard that did not adopt the row");
            sh.p[ks]
        }
    }

    /// Deterministically transfer ownership of up to `batch` of the
    /// hottest queued rows from `victim` to `thief` — the superstep
    /// counterpart of the threaded steal protocol, and the reference
    /// semantics the property tests pin: mass is conserved across the
    /// move, the migrated residual keeps its scheduling priority, and
    /// the solve converges to the same fixed point regardless of who
    /// pushes what. Returns the number of rows moved (0 when the
    /// victim has nothing stealable queued). Attached top-k trackers
    /// are invalidated (rows moved without passing through `add_r`).
    pub fn steal_rows(&mut self, victim: usize, thief: usize, batch: usize) -> usize {
        assert!(victim < self.shards.len(), "victim {victim} out of range");
        assert!(thief < self.shards.len(), "thief {thief} out of range");
        assert_ne!(victim, thief, "a shard cannot steal from itself");
        if batch == 0 {
            return 0;
        }
        // the request precedes the grant even on this synchronous path
        // (the ordering invariant the threaded protocol guarantees and
        // the obs proptests check: thief's track asks, victim's grants)
        if let Some(tr) = &self.trace {
            tr.record(thief, EventKind::StealRequest, victim as u64, 0.0);
        }
        let grant = match self.shards[victim].steal_out(thief, batch) {
            Some(g) => g,
            None => return 0,
        };
        for row in &grant.rows {
            self.owners.set_owner(row.node as usize, thief);
        }
        let moved = self.shards[thief].adopt_rows(grant);
        if let Some(tr) = &self.trace {
            tr.record(victim, EventKind::StealGrant, thief as u64, moved as f64);
        }
        self.stolen_rows += moved as u64;
        self.steal_grants += 1;
        self.bump_head_gen();
        moved
    }

    /// Return every stolen row to its home shard and fold the
    /// ownership overlay back to contiguous bounds. Pending outboxes
    /// are settled first so no forward is left addressed to a thief
    /// that no longer owns the row. Returns the rows moved home.
    ///
    /// The epoch-boundary operations ([`apply_batch`](Self::apply_batch),
    /// [`rebalance`](Self::rebalance), [`gather_into`](Self::gather_into))
    /// call this on entry: node arrivals and bounds migrations only
    /// ever reason about contiguous ownership.
    pub fn repatriate(&mut self) -> usize {
        if self.shards.iter().all(|sh| sh.adopted.is_empty()) {
            debug_assert_eq!(self.owners.displaced(), 0);
            self.owners.fold_contiguous();
            return 0;
        }
        self.exchange();
        let s = self.shards.len();
        let mut homebound: Vec<Vec<StolenRow>> = (0..s).map(|_| Vec::new()).collect();
        let mut moved = 0usize;
        for sh in self.shards.iter_mut() {
            if sh.adopted.is_empty() {
                continue;
            }
            for row in sh.release_adopted() {
                moved += 1;
                homebound[self.part.owner_of(row.node as usize)].push(row);
            }
        }
        for (j, rows) in homebound.into_iter().enumerate() {
            if !rows.is_empty() {
                self.shards[j].restore_grant(StealGrant { rows });
            }
        }
        self.owners = OwnerMap::contiguous(self.part.clone());
        self.bump_head_gen();
        if let Some(tr) = &self.trace {
            tr.record(MONITOR_TRACK, EventKind::Repatriate, moved as u64, 0.0);
        }
        moved
    }

    /// Reconcile the owner map and steal counters with what the
    /// threaded workers actually did (each worker only records its own
    /// grants/adoptions while it exclusively holds its shard).
    pub(crate) fn note_steals(&mut self, rows: u64, grants: u64) {
        self.stolen_rows += rows;
        self.steal_grants += grants;
        let mut owners = OwnerMap::contiguous(self.part.clone());
        for sh in &self.shards {
            for &node in &sh.adopted {
                owners.set_owner(node as usize, sh.id);
            }
        }
        self.owners = owners;
        self.bump_head_gen();
    }

    /// Inject the residual a graph delta creates **directly into the
    /// live shards** — the epoch-resident counterpart of
    /// [`PushState::apply_batch`], with no scatter/gather round-trip
    /// through a global state. `g` must be the graph *after* `delta`
    /// was applied; `self` must be sized to `delta.old_n`.
    ///
    /// Mechanics: pending outboxes are delivered first (so the
    /// injection lands on a settled state and node arrivals never have
    /// to remap an in-flight accumulator), arrived rows extend the last
    /// shard, the teleport/dangling uniform renormalizes through each
    /// shard's replicated `uni` scalar, and every column swap
    /// `α(S'−S)p` is routed to the owning shard as a
    /// [`ResidualFragment`] — the same additive currency the solver
    /// exchanges, so conservation (`Σp + R/(1-α) = 1`) holds by
    /// construction.
    pub fn apply_batch(&mut self, g: &DeltaGraph, delta: &super::AppliedDelta) {
        assert_eq!(self.n, delta.old_n, "sharded state vs delta old_n");
        assert_eq!(g.n(), delta.new_n, "graph vs delta new_n");
        if let Some(tr) = &self.trace {
            tr.record(
                MONITOR_TRACK,
                EventKind::EpochBegin,
                self.cur_stamp,
                (delta.inserted + delta.removed) as f64,
            );
        }
        // stolen rows go home first: arrivals may extend the last
        // shard's rows and the column-swap routing below addresses
        // owners by home bounds
        self.repatriate();
        self.exchange();
        let alpha = self.alpha;
        let (n0, n1) = (delta.old_n, delta.new_n);
        let dangling_to_v = self.pers.as_ref().map_or(false, |p| p.dangling_to_v());

        if n1 != n0 {
            // each shard's uni stands for uni/n per LOCAL row; make it
            // explicit before n changes its meaning (pv's shape is the
            // fixed support of v — n-independent, so it stays pending)
            for sh in self.shards.iter_mut() {
                sh.flush_uni();
            }
            self.grow_to(n1);

            // Whatever part of the right-hand side is uniform e/n gets
            // rescaled by the growth: the teleport column only on the
            // uniform path, the dangling columns only when dangling
            // mass redistributes uniformly. The OLD dangling set is
            // what p converged against: changed sources report their
            // old lists, everyone else kept today's.
            let mut uniform_mass = if self.pers.is_none() { 1.0 - alpha } else { 0.0 };
            if !dangling_to_v {
                let mut old_dangling_mass = 0.0f64;
                let mut changed_iter = delta.changed_sources.iter().peekable();
                for sh in &self.shards {
                    let live = (sh.hi.min(n0)).saturating_sub(sh.lo);
                    for k in 0..live {
                        let u = sh.lo + k;
                        let old_deg = if changed_iter
                            .peek()
                            .map_or(false, |(s, _)| *s as usize == u)
                        {
                            changed_iter.next().unwrap().1.len()
                        } else {
                            g.outdeg(u)
                        };
                        if old_deg == 0 {
                            old_dangling_mass += sh.p[k];
                        }
                    }
                }
                uniform_mass += alpha * old_dangling_mass;
            }
            if uniform_mass != 0.0 {
                let shift_old = uniform_mass * (1.0 / n1 as f64 - 1.0 / n0 as f64);
                let add_new = uniform_mass / n1 as f64;
                for sh in self.shards.iter_mut() {
                    let bs = sh.hi - sh.lo;
                    let live = (sh.hi.min(n0)).saturating_sub(sh.lo);
                    for k in 0..live {
                        sh.add_r(k, shift_old);
                    }
                    for k in live..bs {
                        sh.add_r(k, add_new);
                    }
                }
            }
        }

        // Swap each changed source's old column of αS for its new one,
        // r += α(S'-S)p, batched into one fragment per owning shard.
        // Dangling columns move every shard's replicated scalar —
        // exactly how a dangling push broadcasts at runtime, through
        // whichever pending scalar the redistribution policy uses.
        let s = self.shards.len();
        let mut frags: Vec<ResidualFragment> = (0..s)
            .map(|_| ResidualFragment { entries: Vec::new(), uni: 0.0, pv: 0.0 })
            .collect();
        for (src, old_out) in &delta.changed_sources {
            let u = *src as usize;
            let q = alpha * self.rank_at(u);
            if q == 0.0 {
                continue;
            }
            let mut uni_dq = 0.0f64;
            if old_out.is_empty() {
                uni_dq -= q;
            } else {
                let w = q / old_out.len() as f64;
                for &t in old_out {
                    frags[self.part.owner_of(t as usize)].entries.push((t, -w));
                }
            }
            let new_out = g.out(u);
            if new_out.is_empty() {
                uni_dq += q;
            } else {
                let w = q / new_out.len() as f64;
                for &t in new_out {
                    frags[self.part.owner_of(t as usize)].entries.push((t, w));
                }
            }
            if uni_dq != 0.0 {
                for f in frags.iter_mut() {
                    if dangling_to_v {
                        f.pv += uni_dq;
                    } else {
                        f.uni += uni_dq;
                    }
                }
            }
        }
        for (j, f) in frags.into_iter().enumerate() {
            if !f.entries.is_empty() || f.uni != 0.0 || f.pv != 0.0 {
                self.shards[j].apply_fragment(&f);
            }
        }
    }

    /// Extend the row space to `n1` (node arrivals): interior shard
    /// bounds stay put, the last shard absorbs the new rows. Requires
    /// settled outboxes (the `apply_batch` exchange guarantees it).
    fn grow_to(&mut self, n1: usize) {
        debug_assert!(n1 > self.n);
        debug_assert!(
            self.owners.is_contiguous()
                && self.shards.iter().all(|sh| sh.adopted.is_empty() && sh.lent_count == 0),
            "grow_to requires repatriated shards (apply_batch guarantees it)"
        );
        // n changes every uniform share's meaning and arrivals extend
        // the last shard's rows without an add_r — tracker pools are
        // stale either way
        self.head_gen = super::next_head_gen();
        let mut bounds = self.part.bounds().to_vec();
        *bounds.last_mut().unwrap() = n1;
        let part = Partitioner::from_bounds(bounds);
        self.part = part.clone();
        self.owners = OwnerMap::contiguous(part.clone());
        self.n = n1;
        let last = self.shards.len() - 1;
        for sh in self.shards.iter_mut() {
            sh.part = part.clone();
            sh.n = n1;
            // outboxes addressed to the grown shard were delivered by
            // the exchange; drop the stale allocation so it
            // re-materializes at the new size
            debug_assert!(sh.id == last || sh.outbox[last].is_clear());
            if sh.id != last {
                sh.outbox[last] = Outbox::new(sh.sparse_outbox);
            }
        }
        let sh = &mut self.shards[last];
        let bs1 = n1 - sh.lo;
        sh.hi = n1;
        sh.p.resize(bs1, 0.0);
        sh.r.resize(bs1, 0.0);
        sh.stamp.resize(bs1, 0);
        sh.queue.grow(bs1);
        // arrivals carry no personalization weight, but the last
        // shard's bounds moved — re-derive the (unchanged-in-value)
        // support views so they always match the partition
        self.configure_pers();
    }

    /// Re-balance the shard bounds when churn has skewed the per-shard
    /// out-nnz beyond `factor` times the ideal share. Queued residual,
    /// rank state, epoch stamps, and the conserved mass all migrate;
    /// pending outboxes are delivered first so nothing is in flight
    /// across the bounds change. Returns whether a migration happened.
    ///
    /// After intra-epoch steals the ownership overlay is folded back
    /// first ([`repatriate`](Self::repatriate)): the re-balancer
    /// reasons about contiguous blocks only, so stolen rows return
    /// home *even when the skew check then declines to move the
    /// bounds*. That is the contract — `rebalance` always leaves a
    /// contiguous [`OwnerMap`], migrated bounds or not.
    ///
    /// O(n) when it fires, O(n) for the skew scan when it does not —
    /// call it at epoch boundaries, not inside the push loop.
    pub fn rebalance(&mut self, g: &DeltaGraph, factor: f64) -> bool {
        assert_eq!(self.n, g.n(), "sharded state sized to a different graph");
        assert!(factor >= 1.0, "imbalance factor must be >= 1");
        self.repatriate();
        let lens: Vec<usize> = (0..self.n).map(|u| g.outdeg(u)).collect();
        if self.part.weight_imbalance(&lens) <= factor {
            return false;
        }
        let new_part = Partitioner::balanced_nnz_lens(&lens, self.requested_shards);
        if new_part.bounds() == self.part.bounds() {
            return false;
        }
        self.exchange();
        self.adopt_partition(new_part);
        true
    }

    /// Migrate all row state onto a new partition. Outboxes must be
    /// empty (exchange first). The replicated per-shard uniform scalars
    /// are unified onto a common value — the differences fold into the
    /// materialized residual, an exact representation change — so a row
    /// crossing a bounds line carries the same pending mass on both
    /// sides.
    fn adopt_partition(&mut self, part: Partitioner) {
        debug_assert!(
            self.shards.iter().all(|sh| sh.adopted.is_empty() && sh.lent_count == 0),
            "adopt_partition requires repatriated shards"
        );
        self.head_gen = super::next_head_gen(); // rows migrated: pools are stale
        let nf = self.n as f64;
        let u_common = self.shards[0].uni;
        let pv_common = self.shards[0].pv;
        for sh in self.shards.iter_mut() {
            debug_assert!(sh.acc_mass == 0.0 && sh.outbox.iter().all(Outbox::is_clear));
            let d = (sh.uni - u_common) / nf;
            if d != 0.0 {
                // raw writes, not add_r: this is a representation change
                // (pending-uniform share -> materialized residual), so it
                // must not stamp every row as epoch-touched; the retiring
                // generation's queue/tally fields are rebuilt from `r`
                // below and never read again
                for v in sh.r.iter_mut() {
                    *v += d;
                }
            }
            sh.uni = u_common;
            // same unification for the personalization scalar: the
            // difference folds into the residual over the local support
            // (exact — a shard's pv slice lives only on those rows)
            let d_pv = sh.pv - pv_common;
            if d_pv != 0.0 {
                let scale = d_pv / sh.vtotal;
                for &(k, w) in &sh.vlocal {
                    sh.r[k as usize] += scale * w;
                }
            }
            sh.pv = pv_common;
        }
        // snapshot the global vectors, retiring the old generation
        let mut p = vec![0.0f64; self.n];
        let mut r = vec![0.0f64; self.n];
        let mut stamp = vec![0u64; self.n];
        for sh in &self.shards {
            p[sh.lo..sh.hi].copy_from_slice(&sh.p);
            r[sh.lo..sh.hi].copy_from_slice(&sh.r);
            stamp[sh.lo..sh.hi].copy_from_slice(&sh.stamp);
            self.carried_pushes += sh.pushes;
        }
        self.part = part.clone();
        self.owners = OwnerMap::contiguous(part.clone());
        let s = part.p();
        let sparse = self.outbox_policy.sparse_for(s);
        let mut shards: Vec<PushShard> = Vec::with_capacity(s);
        for id in 0..s {
            let mut sh = PushShard::new(id, &part, self.n, self.alpha, sparse);
            sh.p.copy_from_slice(&p[sh.lo..sh.hi]);
            sh.r.copy_from_slice(&r[sh.lo..sh.hi]);
            sh.stamp.copy_from_slice(&stamp[sh.lo..sh.hi]);
            let (queue, l1) = BucketQueue::seeded_from(&sh.r);
            sh.queue = queue;
            sh.r_l1 = l1;
            sh.r_sum = sh.r.iter().sum();
            sh.p_sum = sh.p.iter().sum();
            sh.uni = u_common;
            sh.pv = pv_common;
            sh.cur_stamp = self.cur_stamp;
            if self.cur_stamp > 0 {
                sh.touched = sh.stamp.iter().filter(|&&t| t == self.cur_stamp).count();
            }
            shards.push(sh);
        }
        self.shards = shards;
        self.configure_pers();
    }

    /// Assemble the current global rank estimate (copy). Contiguous
    /// ownership is two memcpys per shard; stolen rows are patched in
    /// from their owners' overflow slots (a lent row's home slot reads
    /// zero by construction).
    pub fn ranks(&self) -> Vec<f64> {
        let mut x = vec![0.0f64; self.n];
        for sh in &self.shards {
            x[sh.lo..sh.hi].copy_from_slice(&sh.p[..sh.hi - sh.lo]);
        }
        if !self.owners.is_contiguous() {
            for sh in &self.shards {
                let bs = sh.hi - sh.lo;
                for (slot, &node) in sh.adopted.iter().enumerate() {
                    x[node as usize] = sh.p[bs + slot];
                }
            }
        }
        x
    }

    /// Deliver every pending outbox and uniform broadcast, all-to-all,
    /// in shard order (deterministic), repeating until a round moves
    /// nothing: applying a fragment at a home shard can *forward* mass
    /// for a lent row back into an outbox, so one round is not always
    /// enough while rows are stolen (forwards are one-hop, so this
    /// settles in at most one extra round — and without steals the
    /// second round is an empty sweep). Returns fragments delivered.
    pub fn exchange(&mut self) -> u64 {
        let s = self.shards.len();
        let mut total = 0u64;
        loop {
            let mut frags: Vec<(usize, ResidualFragment)> = Vec::new();
            for i in 0..s {
                self.shards[i].absorb_self_uniform();
                for j in 0..s {
                    if j == i {
                        continue;
                    }
                    if let Some(f) = self.shards[i].take_fragment(j) {
                        if let Some(tr) = &self.trace {
                            tr.record(i, EventKind::FragSend, j as u64, f.entries.len() as f64);
                        }
                        frags.push((j, f));
                    }
                }
                // every outbox slot is now exactly 0.0 — pin the
                // incremental tallies back to zero so defer/take float
                // residue cannot accumulate across epochs
                self.shards[i].acc_mass = 0.0;
                self.shards[i].acc_sum = 0.0;
            }
            if frags.is_empty() {
                break;
            }
            total += frags.len() as u64;
            for (j, f) in frags {
                self.shards[j].apply_fragment(&f);
            }
        }
        total
    }

    /// Residual mass `Σ_s (‖r_s‖₁ + |uni_s|·|B_s|/n)` plus anything
    /// still parked in outboxes — O(shards), read from the
    /// incrementally maintained tallies. Debug builds verify the
    /// tallies against a dense re-sweep; callers that need a
    /// drift-proof figure (convergence confirmation) use
    /// [`residual_recompute`](Self::residual_recompute), the exact
    /// fallback. Quiet-window pollers and per-epoch reporting stay
    /// O(shards) here instead of paying O(n) per call.
    pub fn residual_exact(&mut self) -> f64 {
        let est: f64 = self.shards.iter().map(|sh| sh.residual_estimate()).sum();
        debug_assert!(
            {
                let dense: f64 = self
                    .shards
                    .iter()
                    .map(|sh| {
                        let l1: f64 = sh.r.iter().map(|v| v.abs()).sum();
                        let nf = sh.n as f64;
                        let mut d = l1 + sh.uni.abs() * (sh.hi - sh.lo) as f64 / nf;
                        d += sh.pv.abs() * sh.vshare() / sh.vtotal;
                        for ob in &sh.outbox {
                            match ob {
                                Outbox::Dense { acc, fwd, .. } => {
                                    d += acc.iter().map(|w| w.abs()).sum::<f64>();
                                    d += fwd.iter().map(|(_, w)| w.abs()).sum::<f64>();
                                }
                                Outbox::Sparse(map) => {
                                    d += map.values().map(|w| w.abs()).sum::<f64>();
                                }
                            }
                        }
                        for (j, u) in sh.out_uni.iter().enumerate() {
                            let rows = sh.part.bounds()[j + 1] - sh.part.bounds()[j];
                            d += u.abs() * rows as f64 / nf;
                        }
                        for (j, q) in sh.out_pv.iter().enumerate() {
                            d += q.abs() * sh.vshares[j] / sh.vtotal;
                        }
                        d
                    })
                    .sum();
                (est - dense).abs() <= 1e-7 * (1.0 + dense)
            },
            "incremental residual tally drifted from the dense re-sweep"
        );
        est
    }

    /// Dense re-tally of the residual mass (clears incremental drift in
    /// every shard before summing) — the exact fallback behind
    /// [`residual_exact`](Self::residual_exact).
    pub fn residual_recompute(&mut self) -> f64 {
        for sh in self.shards.iter_mut() {
            sh.recompute_r_l1();
            sh.recompute_acc_sums();
        }
        self.shards.iter().map(|sh| sh.residual_estimate()).sum()
    }

    /// The conserved mass `Σp + R/(1-α)` (signed residuals, pending
    /// outboxes included). Equals [`target_mass`](Self::target_mass) —
    /// `Σv`, i.e. 1 on the uniform path — to float accumulation error
    /// after every push, exchange, and flush: the invariant that makes
    /// residual shipping safe. O(shards): rank and residual sums are
    /// carried incrementally (debug builds cross-check the dense
    /// sweep inside the per-shard signed-residual tally).
    pub fn mass(&self) -> f64 {
        let mut m = 0.0f64;
        for sh in &self.shards {
            m += sh.p_sum + sh.signed_residual() / (1.0 - self.alpha);
        }
        m
    }

    /// Deterministic superstep loop: drain every shard (bounded by
    /// [`round_pushes`](Self::round_pushes)), deliver every outbox,
    /// repeat until the global residual drops below `tol` or the push
    /// budget is exhausted. Single-threaded and bit-reproducible — the
    /// reference semantics that [`run_threaded_push`] relaxes onto real
    /// threads.
    ///
    /// [`run_threaded_push`]: crate::asynciter::threads::run_threaded_push
    pub fn solve(&mut self, g: &DeltaGraph, tol: f64, max_pushes: u64) -> ShardSolveStats {
        assert_eq!(self.n, g.n(), "sharded state sized to a different graph");
        assert!(tol > 0.0, "tol must be positive");
        let s = self.shards.len();
        // per-shard drain target: an equal split of half the global
        // tolerance, so s shards below target sum below tol
        let target = 0.5 * tol / s as f64;
        let mut pushes = 0u64;
        let mut rounds = 0u64;
        let mut fragments = 0u64;
        // cloned handle so recording never contends with the shard
        // iteration borrows (an Arc clone per solve, not per round)
        let trace = self.trace.clone();
        let converged = loop {
            let mut round_pushes = 0u64;
            let budget = self.round_pushes;
            for sh in self.shards.iter_mut() {
                let drained = sh.drain(g, target, budget);
                if drained > 0 {
                    if let Some(tr) = &trace {
                        tr.record(sh.id, EventKind::PushBatch, drained, sh.r_l1);
                    }
                }
                round_pushes += drained;
            }
            pushes += round_pushes;
            let delivered = self.exchange();
            fragments += delivered;
            rounds += 1;
            // per-superstep residual-decay samples — the deterministic
            // counterpart of the threaded monitor's periodic sweep
            if let Some(tr) = &trace {
                let t = tr.now_us();
                for sh in &self.shards {
                    tr.push_sample(Sample {
                        t_us: t,
                        shard: sh.id as u32,
                        residual: sh.residual_estimate(),
                        queued: sh.r_l1,
                        in_flight: 0,
                        pressure: sh.stealable_r_l1(),
                    });
                }
            }
            let est: f64 = self.shards.iter().map(|sh| sh.residual_estimate()).sum();
            if est < tol {
                // confirm against a dense re-tally before declaring
                // victory (the incremental tallies can drift low)
                if self.residual_recompute() < tol {
                    break true;
                }
            }
            if pushes >= max_pushes {
                break false;
            }
            if round_pushes == 0 && delivered == 0 {
                // nothing moved: force the pending uniforms out, and if
                // that leaves nothing either, the tally drift was all
                // that kept us looping
                let pending = self.shards.iter().any(|sh| sh.uni != 0.0 || sh.pv != 0.0);
                if pending {
                    for sh in self.shards.iter_mut() {
                        sh.flush_uni();
                        sh.flush_v();
                    }
                } else {
                    break self.residual_recompute() < tol;
                }
            }
        };
        ShardSolveStats {
            pushes,
            rounds,
            fragments,
            residual: self.residual_recompute(),
            converged,
        }
    }

    /// Gather back into a global [`PushState`]: pending outboxes are
    /// delivered and the state adopts the assembled vectors (epoch
    /// stamps and lifetime counters are preserved; the parallel-phase
    /// pushes are credited to the state's counter).
    ///
    /// The per-shard uniform scalars decompose exactly into a common
    /// part — which becomes the state's global pending-uniform `rd` —
    /// plus per-shard differences folded into the residual. Any split
    /// is exact (`rd/n` lands on every row); picking shard 0's value as
    /// the common part means the frequent "no shard flushed or pushed a
    /// dangling row" case folds nothing, leaving untouched rows
    /// bit-identical so the epoch's touched-node accounting stays
    /// churn-proportional.
    pub fn gather_into(mut self, state: &mut PushState) {
        assert_eq!(state.n(), self.n, "gather into a different-sized state");
        assert!(
            match (state.personalization(), &self.pers) {
                (None, None) => true,
                (Some(a), Some(b)) => Arc::ptr_eq(a, b) || **a == **b,
                _ => false,
            },
            "gather into a state with a different personalization vector"
        );
        self.repatriate();
        self.exchange();
        let nf = self.n as f64;
        let u_common = self.shards[0].uni;
        let pv_common = self.shards[0].pv;
        let mut p = vec![0.0f64; self.n];
        let mut r = vec![0.0f64; self.n];
        // retired shard generations (rebalance) count toward the credit
        let mut pushes = self.carried_pushes;
        for sh in &self.shards {
            let add = (sh.uni - u_common) / nf;
            for k in 0..sh.hi - sh.lo {
                p[sh.lo + k] = sh.p[k];
                r[sh.lo + k] = sh.r[k] + add;
            }
            // fold this shard's pv difference into its local support —
            // pv_common rides back as the state's pending-v scalar
            let d_pv = sh.pv - pv_common;
            if d_pv != 0.0 {
                let scale = d_pv / sh.vtotal;
                for &(k, w) in &sh.vlocal {
                    r[sh.lo + k as usize] += scale * w;
                }
            }
            pushes += sh.pushes;
        }
        state.adopt_parts(p, r, u_common, pv_common);
        state.add_pushes(pushes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, EdgeList};
    use crate::stream::{power_method_f64, power_method_pers, UpdateBatch};
    use crate::util::Rng;

    fn l1(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    fn web(n: usize, seed: u64) -> DeltaGraph {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        DeltaGraph::from_edgelist(&el)
    }

    #[test]
    fn sharded_cold_solve_matches_power_method() {
        let g = web(2_000, 31);
        for shards in [1usize, 2, 4, 7] {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            let st = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "shards {shards}: residual {}", st.residual);
            assert!((sp.mass() - 1.0).abs() < 1e-9, "shards {shards}: mass {}", sp.mass());
            let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
            let d = l1(&sp.ranks(), &xref);
            assert!(d < 1e-9, "shards {shards}: drift {d}");
            if shards > 1 {
                assert!(st.fragments > 0, "no residual fragments exchanged");
            }
        }
    }

    #[test]
    fn sharded_solve_is_deterministic() {
        let g = web(1_200, 32);
        let run = || {
            let mut sp = ShardedPush::new(&g, 0.85, 4);
            let st = sp.solve(&g, 1e-10, u64::MAX);
            (st.pushes, st.rounds, sp.ranks())
        };
        let (pa, ra, xa) = run();
        let (pb, rb, xb) = run();
        assert_eq!(pa, pb);
        assert_eq!(ra, rb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn scatter_gather_roundtrip_preserves_solution() {
        let g = web(1_500, 33);
        let mut state = PushState::new(g.n(), 0.85);
        state.begin_epoch();
        state.solve(&g, 1e-11, u64::MAX);
        let before = state.ranks().to_vec();
        let sp = ShardedPush::from_state(&state, &g, 4);
        assert!((sp.mass() - 1.0).abs() < 1e-9, "scatter broke mass: {}", sp.mass());
        sp.gather_into(&mut state);
        // gathering an untouched sharded state must not move the ranks
        assert!(l1(state.ranks(), &before) < 1e-15);
        // and the state remains a working solver
        let st = state.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
    }

    #[test]
    fn warm_start_through_shards_matches_cold() {
        let mut g = web(1_200, 34);
        let mut inc = PushState::new(g.n(), 0.85);
        inc.begin_epoch();
        inc.solve(&g, 1e-11, u64::MAX);
        let mut rng = Rng::new(35);
        for round in 0..3 {
            let n = g.n();
            let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
            for _ in 0..30 {
                batch
                    .insert
                    .push((rng.range(0, n + 2) as u32, rng.range(0, n) as u32));
            }
            let delta = g.apply(&batch).unwrap();
            inc.begin_epoch();
            inc.apply_batch(&g, &delta);
            // solve the epoch through the sharded engine
            let mut sp = ShardedPush::from_state(&inc, &g, 3);
            let st = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "round {round}");
            assert!((sp.mass() - 1.0).abs() < 1e-9, "round {round}: mass {}", sp.mass());
            sp.gather_into(&mut inc);

            let mut cold = PushState::new(g.n(), 0.85);
            cold.begin_epoch();
            cold.solve(&g, 1e-11, u64::MAX);
            let d = l1(inc.ranks(), cold.ranks());
            assert!(d < 1e-8, "round {round}: sharded warm vs cold drift {d}");
        }
    }

    #[test]
    fn dangling_heavy_graph_converges_sharded() {
        // star + extra dangling rows: uniform broadcasts dominate
        let el = EdgeList::from_edges(40, (1..20).map(|i| (0u32, i as u32)).collect())
            .unwrap();
        let g = DeltaGraph::from_edgelist(&el);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let st = sp.solve(&g, 1e-12, u64::MAX);
        assert!(st.converged);
        assert!((sp.mass() - 1.0).abs() < 1e-9);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-10);
    }

    #[test]
    fn more_shards_than_rows_degrades_gracefully() {
        let el = generators::chain(5);
        let g = DeltaGraph::from_edgelist(&el);
        let mut sp = ShardedPush::new(&g, 0.85, 16);
        assert_eq!(sp.shard_count(), 5);
        let st = sp.solve(&g, 1e-12, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-10);
    }

    #[test]
    fn budget_cap_reports_unconverged_but_conserves_mass() {
        let g = web(2_000, 36);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        sp.round_pushes = 64;
        let st = sp.solve(&g, 1e-12, 500);
        assert!(!st.converged);
        assert!(st.residual > 1e-12);
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        // finishing the interrupted solve still lands on the fixed point
        sp.round_pushes = 4096;
        let st2 = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st2.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn resident_apply_batch_matches_scatter_roundtrip() {
        // the tentpole equivalence at unit scale: injecting a delta into
        // the LIVE shards lands on the same fixed point as the
        // scatter -> inject -> re-scatter path, and conserves mass at
        // every stage (before the solve, not just after)
        let mut g = web(1_000, 44);
        let mut resident = ShardedPush::new(&g, 0.85, 3);
        resident.solve(&g, 1e-11, u64::MAX);
        let mut state = PushState::new(g.n(), 0.85);
        state.begin_epoch();
        state.solve(&g, 1e-11, u64::MAX);
        let mut rng = Rng::new(45);
        for round in 0..3 {
            let n = g.n();
            let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
            for _ in 0..40 {
                batch
                    .insert
                    .push((rng.range(0, n + 2) as u32, rng.range(0, n) as u32));
            }
            let mut edges = Vec::new();
            g.for_each_edge(|s, d| edges.push((s, d)));
            for _ in 0..20 {
                batch.remove.push(edges[rng.range(0, edges.len())]);
            }
            let delta = g.apply(&batch).unwrap();

            resident.begin_epoch();
            resident.apply_batch(&g, &delta);
            let m = resident.mass();
            assert!((m - 1.0).abs() < 1e-9, "round {round}: mass after inject {m}");
            assert!(resident.touched() > 0, "round {round}: injection touched nothing");
            let st = resident.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "round {round}");

            state.begin_epoch();
            state.apply_batch(&g, &delta);
            let mut sp = ShardedPush::from_state(&state, &g, 3);
            let st2 = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st2.converged, "round {round}");
            sp.gather_into(&mut state);

            let d = l1(&resident.ranks(), state.ranks());
            assert!(d < 1e-9, "round {round}: resident vs roundtrip drift {d}");
        }
    }

    #[test]
    fn sharded_ppr_matches_personalized_power_method() {
        let g = web(2_000, 51);
        for dangling_to_v in [true, false] {
            let pers = Arc::new(
                Personalization::from_entries(vec![(17, 0.75), (900, 0.25)], dangling_to_v)
                    .unwrap(),
            );
            for shards in [1usize, 3, 5] {
                let mut sp = ShardedPush::new_personalized(&g, 0.85, shards, Arc::clone(&pers));
                let st = sp.solve(&g, 1e-11, u64::MAX);
                assert!(st.converged, "shards {shards}: residual {}", st.residual);
                assert!(
                    (sp.mass() - sp.target_mass()).abs() < 1e-9,
                    "dangling_to_v={dangling_to_v} shards {shards}: mass {}",
                    sp.mass()
                );
                let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-12, 10_000);
                let d = l1(&sp.ranks(), &xref);
                assert!(
                    d < 1e-9,
                    "dangling_to_v={dangling_to_v} shards {shards}: drift {d}"
                );
            }
        }
    }

    #[test]
    fn resident_ppr_apply_batch_tracks_churn() {
        // pv end-to-end: churn with arrivals injected into the LIVE
        // personalized shards (dangling_to_v exercises the pv
        // broadcast through apply_batch, exchange, and rebalance)
        let mut g = web(1_000, 52);
        let pers = Arc::new(
            Personalization::from_entries(vec![(5, 0.6), (321, 0.4)], true).unwrap(),
        );
        let mut sp = ShardedPush::new_personalized(&g, 0.85, 3, Arc::clone(&pers));
        sp.solve(&g, 1e-11, u64::MAX);
        let mut rng = Rng::new(53);
        for round in 0..3 {
            let n = g.n();
            let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
            for _ in 0..40 {
                batch
                    .insert
                    .push((rng.range(0, n + 2) as u32, rng.range(0, n) as u32));
            }
            let mut edges = Vec::new();
            g.for_each_edge(|s, d| edges.push((s, d)));
            for _ in 0..20 {
                batch.remove.push(edges[rng.range(0, edges.len())]);
            }
            let delta = g.apply(&batch).unwrap();
            sp.begin_epoch();
            sp.apply_batch(&g, &delta);
            let m = sp.mass();
            assert!(
                (m - sp.target_mass()).abs() < 1e-9,
                "round {round}: mass after inject {m}"
            );
            sp.rebalance(&g, 1.05);
            let st = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "round {round}");
            let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-13, 100_000);
            let d = l1(&sp.ranks(), &xref);
            assert!(d < 1e-8, "round {round}: resident PPR drift {d}");
        }
    }

    #[test]
    fn ppr_scatter_gather_roundtrip_preserves_solution() {
        let g = web(1_200, 54);
        let pers = Arc::new(Personalization::single_source(7));
        let mut state = PushState::new_personalized(g.n(), 0.85, Arc::clone(&pers));
        state.begin_epoch();
        state.solve(&g, 1e-11, u64::MAX);
        let before = state.ranks().to_vec();
        let sp = ShardedPush::from_state(&state, &g, 4);
        assert!(
            (sp.mass() - sp.target_mass()).abs() < 1e-9,
            "scatter broke mass: {}",
            sp.mass()
        );
        sp.gather_into(&mut state);
        assert!(l1(state.ranks(), &before) < 1e-15);
        let st = state.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
    }

    #[test]
    fn rebalance_is_noop_below_the_factor() {
        let g = web(1_000, 41);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let bounds = sp.partitioner().bounds().to_vec();
        let lens: Vec<usize> = (0..g.n()).map(|u| g.outdeg(u)).collect();
        let imb = sp.partitioner().weight_imbalance(&lens);
        assert!(!sp.rebalance(&g, imb + 0.1), "fresh balanced bounds must not move");
        assert_eq!(sp.partitioner().bounds(), &bounds[..]);
        assert_eq!(sp.total_pushes(), 0);
    }

    #[test]
    fn rebalance_after_hub_arrival_preserves_state() {
        let mut g = web(400, 42);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        // five arriving hubs, all owned by the last shard: per-shard nnz
        // skews hard in one place
        let n = g.n();
        let mut batch = UpdateBatch { new_nodes: 5, ..Default::default() };
        for h in 0..5u32 {
            for t in 0..n {
                batch.insert.push(((n + h as usize) as u32, t as u32));
            }
        }
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        let lens: Vec<usize> = (0..g.n()).map(|u| g.outdeg(u)).collect();
        let before = sp.partitioner().weight_imbalance(&lens);
        assert!(before > 1.1, "hub arrival should skew the bounds: {before}");

        let tp0 = sp.total_pushes();
        let r0 = sp.residual_exact();
        let m0 = sp.mass();
        assert!(sp.rebalance(&g, 1.1), "skew {before} must trigger a migration");
        // nothing lost across the bounds migration
        assert_eq!(sp.total_pushes(), tp0, "rebalance must not spend pushes");
        let r1 = sp.residual_exact();
        assert!((r0 - r1).abs() < 1e-9, "queued residual moved: {r0} vs {r1}");
        assert!((sp.mass() - m0).abs() < 1e-12, "mass moved: {m0} vs {}", sp.mass());
        let after = sp.partitioner().weight_imbalance(&lens);
        assert!(after <= before, "rebalance made skew worse: {before} -> {after}");
        // and the migrated state still lands on the reference
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn rebalance_mid_solve_keeps_queued_residual() {
        // interrupt a solve (hot queues, residual everywhere), skew the
        // graph, rebalance: the queued mass must survive the migration
        // even though the per-shard uniform scalars have diverged
        let mut g = web(800, 46);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        sp.round_pushes = 128;
        let st = sp.solve(&g, 1e-12, 600);
        assert!(!st.converged, "budget too generous for this test");
        let n = g.n();
        let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
        for t in 0..n {
            batch.insert.push((n as u32, t as u32));
        }
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        let tp0 = sp.total_pushes();
        let r0 = sp.residual_exact();
        let m0 = sp.mass();
        assert!((m0 - 1.0).abs() < 1e-9);
        if sp.rebalance(&g, 1.05) {
            assert_eq!(sp.total_pushes(), tp0);
            let r1 = sp.residual_exact();
            // the uniform unification folds signed mass into |r|, so the
            // L1 tally may shift by cancellation — but only a little
            assert!((r0 - r1).abs() < 1e-7 * (1.0 + r0), "residual jumped: {r0} vs {r1}");
            assert!((sp.mass() - m0).abs() < 1e-10, "mass moved across migration");
        }
        sp.round_pushes = 4096;
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn rebalance_survives_mass_deletion_with_more_shards_than_weight() {
        // heavy deletion: 8 shards but only a handful of rows still
        // carry out-edges — the re-cut pads empty blocks and the solver
        // keeps working
        let mut g = web(300, 43);
        let mut sp = ShardedPush::new(&g, 0.85, 8);
        sp.solve(&g, 1e-10, u64::MAX);
        let mut batch = UpdateBatch::default();
        g.for_each_edge(|s, d| {
            if s >= 10 {
                batch.remove.push((s, d));
            }
        });
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        let fired = sp.rebalance(&g, 1.5);
        assert_eq!(sp.shard_count(), 8, "shard count must survive the re-cut");
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9, "fired={fired}");
    }

    #[test]
    fn resident_epoch_touched_counts_are_churn_proportional() {
        // warm epochs must not touch the whole graph: the resident
        // injection + drain only visits rows the churn actually reaches
        let mut g = web(2_000, 47);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        sp.solve(&g, 1e-10, u64::MAX);
        // a guaranteed-new edge, so the delta is never a no-op
        let t = (0..g.n()).find(|&t| !g.has_edge(17, t as u32)).unwrap();
        let delta = g
            .apply(&UpdateBatch {
                new_nodes: 0,
                insert: vec![(17, t as u32)],
                remove: vec![],
            })
            .unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        let touched = sp.touched();
        assert!(touched > 0);
        assert!(
            touched < g.n() / 2,
            "single-edge epoch touched {touched} of {} rows",
            g.n()
        );
    }

    #[test]
    fn steal_conserves_mass_and_still_reaches_the_fixed_point() {
        // interrupt a cold solve (hot queues everywhere), move rows
        // between shards deterministically, and finish: the fixed point
        // must not care who pushed what — the D-Iteration license work
        // stealing cashes in
        let g = web(1_500, 51);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        sp.round_pushes = 256;
        let st = sp.solve(&g, 1e-12, 1_000);
        assert!(!st.converged, "budget too generous for this test");
        let m0 = sp.mass();
        assert!((m0 - 1.0).abs() < 1e-9);
        let moved = sp.steal_rows(0, 3, 16) + sp.steal_rows(1, 2, 16);
        assert!(moved > 0, "hot queues must yield stealable rows");
        assert_eq!(sp.steal_totals().0, moved as u64);
        assert!(!sp.owner_map().is_contiguous());
        assert_eq!(sp.owner_map().displaced(), moved);
        // the move itself created or destroyed nothing
        assert!((sp.mass() - m0).abs() < 1e-12, "steal moved mass: {}", sp.mass());
        // rank reads route to the owner mid-steal
        let x = sp.ranks();
        for u in 0..g.n() {
            assert_eq!(sp.rank_at(u), x[u], "rank_at vs ranks at {u}");
        }
        sp.round_pushes = 4096;
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        assert!((sp.mass() - 1.0).abs() < 1e-9);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let d = l1(&sp.ranks(), &xref);
        assert!(d < 1e-9, "steal-interleaved solve drifted {d}");
    }

    #[test]
    fn mass_for_a_lent_row_forwards_to_its_owner() {
        let g = web(600, 52);
        let mut sp = ShardedPush::new(&g, 0.85, 2);
        sp.round_pushes = 128;
        sp.solve(&g, 1e-12, 400);
        let moved = sp.steal_rows(0, 1, 4);
        assert!(moved > 0);
        let node = sp.shards[1].adopted[0];
        // address residual at the stolen row's HOME shard: it must not
        // accumulate there (the slot is lent) but reach the thief
        let frag = ResidualFragment { entries: vec![(node, 0.125)], uni: 0.0, pv: 0.0 };
        let m0 = sp.mass();
        let k_home = node as usize - sp.shards[0].lo;
        sp.shards[0].apply_fragment(&frag);
        assert_eq!(sp.shards[0].r[k_home], 0.0, "lent slot accumulated mass");
        assert!((sp.mass() - m0 - 0.125 / (1.0 - 0.85)).abs() < 1e-9);
        sp.exchange();
        let bs = sp.shards[1].home_size();
        let slot = sp.shards[1].adopted_slot_of(node as usize).unwrap();
        assert!(slot >= bs);
        assert!(sp.shards[1].r[slot] >= 0.125 - 1e-12, "forward never arrived");
        // remove the injected mass again so the fixed point is untouched
        let undo = ResidualFragment { entries: vec![(node, -0.125)], uni: 0.0, pv: 0.0 };
        sp.shards[1].apply_fragment(&undo);
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn repatriate_returns_rows_and_folds_the_owner_map() {
        let g = web(900, 53);
        let mut sp = ShardedPush::new(&g, 0.85, 3);
        sp.round_pushes = 256;
        sp.solve(&g, 1e-12, 700);
        // settle outboxes now so the repatriation-time exchange cannot
        // deliver left-over solve mass and inflate the touched count
        sp.exchange();
        let before_touch = {
            sp.begin_epoch();
            // touch some state so the stamp bookkeeping has something
            // to preserve across the moves
            sp.shards[0].flush_uni();
            sp.touched()
        };
        let moved = sp.steal_rows(0, 2, 8);
        assert!(moved > 0);
        assert_eq!(sp.touched(), before_touch, "steal changed the touched count");
        let m0 = sp.mass();
        let x0 = sp.ranks();
        let returned = sp.repatriate();
        assert_eq!(returned, moved);
        assert!(sp.owner_map().is_contiguous(), "repatriate must fold the overlay");
        assert!(sp.shards.iter().all(|sh| sh.adopted.is_empty() && sh.lent_count == 0));
        assert_eq!(sp.touched(), before_touch, "repatriation changed the touched count");
        assert!((sp.mass() - m0).abs() < 1e-9);
        // repatriation is a pure representation move (modulo outbox
        // settlement, which exchange() applies on both sides)
        let x1 = sp.ranks();
        assert!(l1(&x0, &x1) < 1e-12, "repatriation moved rank mass");
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn rebalance_after_steal_folds_ownership_before_recutting() {
        // the regression pinned by ISSUE 5's fix item: a rebalance that
        // fires while rows are stolen must fold the non-contiguous
        // OwnerMap back to contiguous bounds and lose nothing
        let mut g = web(500, 54);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        sp.round_pushes = 128;
        sp.solve(&g, 1e-12, 500);
        assert!(sp.steal_rows(0, 1, 8) > 0);
        assert!(!sp.owner_map().is_contiguous());

        // skew the graph so the re-cut actually fires
        let n = g.n();
        let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
        for t in 0..n {
            batch.insert.push((n as u32, t as u32));
        }
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta); // folds ownership already (contract)
        assert!(sp.owner_map().is_contiguous());
        assert!(sp.steal_rows(1, 0, 8) > 0, "re-steal after the batch");
        let tp0 = sp.total_pushes();
        let m0 = sp.mass();
        let fired = sp.rebalance(&g, 1.05);
        assert!(sp.owner_map().is_contiguous(), "rebalance left a displaced OwnerMap");
        assert_eq!(sp.total_pushes(), tp0);
        assert!((sp.mass() - m0).abs() < 1e-9, "fold/re-cut moved mass");
        // and a rebalance whose skew check declines still folds
        // (documented contract): steal again, call with a huge factor
        sp.steal_rows(0, 1, 4);
        assert!(!sp.rebalance(&g, 1e9), "factor 1e9 must never migrate bounds");
        assert!(sp.owner_map().is_contiguous());
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged, "fired={fired}");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn steal_grant_restore_is_lossless() {
        // the bounded-channel defer path: a grant that cannot ship is
        // restored to the victim bit-for-bit
        let g = web(700, 55);
        let mut sp = ShardedPush::new(&g, 0.85, 2);
        sp.round_pushes = 128;
        sp.solve(&g, 1e-12, 300);
        let m0 = sp.mass();
        let r0 = sp.residual_exact();
        let x0 = sp.ranks();
        let grant = sp.shards[0].steal_out(1, 8).expect("hot queue must grant");
        sp.shards[0].restore_grant(grant);
        assert_eq!(sp.shards[0].lent_count, 0);
        assert!((sp.mass() - m0).abs() < 1e-12);
        assert!((sp.residual_exact() - r0).abs() < 1e-9);
        assert!(l1(&sp.ranks(), &x0) < 1e-15);
        // the restored queue still drives the solve home
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(&sp.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn fragment_defer_and_restore_is_lossless() {
        let g = web(800, 37);
        let mut sp = ShardedPush::new(&g, 0.85, 2);
        // run a few rounds without exchanging so outboxes fill
        for sh in sp.shards.iter_mut() {
            sh.drain(&g, 1e-12, 2_000);
        }
        let m0 = sp.mass();
        assert!((m0 - 1.0).abs() < 1e-9, "mass before defer {m0}");
        // take a fragment and put it back — mass must not move
        if let Some(frag) = sp.shards[0].take_fragment(1) {
            sp.shards[0].restore_fragment(1, frag);
        }
        let m1 = sp.mass();
        assert!((m0 - m1).abs() < 1e-12, "defer/restore moved mass: {m0} vs {m1}");
        // delivering it is equally conservative
        sp.exchange();
        assert!((sp.mass() - 1.0).abs() < 1e-9);
    }
}
