//! Certified top-k rank maintenance — the serving-path workload.
//!
//! The paper's motivating use-case is *serving*: PageRank orders the
//! result set a search engine returns (§1), so what the asynchronous
//! iteration owes the caller is a **correct head of the ranking**, not
//! a fully converged vector. This module maintains that head
//! incrementally over the push solvers and — the part that makes it a
//! serving primitive rather than a heuristic — *certifies* it: using
//! the push invariant `x* = p + (I−αS)^{-1}ρ` (ρ = materialized
//! residual + pending uniform/personalization shares), every node's
//! true rank is enclosed in an interval around its **center**
//! `c_i = p_i + ρ_i`:
//!
//! ```text
//!     x*_i ∈ [ c_i − α·R⁻/(1−α) − U⁻/(1−α),  c_i + α·R⁺/(1−α) + U⁺/(1−α) ]
//! ```
//!
//! where `R± = Σ ρ±` splits the *located* residual (we know which node
//! it sits on — its own t=0 term enters the center exactly, only the
//! diffused `t ≥ 1` tail is bounded through `α/(1−α)`) and `U±` is
//! residual whose destination is unknown at check time (outbox /
//! in-flight mass, bounded at full `1/(1−α)` weight). `S` is
//! column-stochastic, so `‖S^t ρ±‖₁ = ‖ρ±‖₁` and the enclosure is
//! sound at **every** superstep, converged or not — the D-Iteration
//! error-certificate idea (Hong et al.) applied per node. When the
//! k-th head member's lower bound strictly exceeds every outsider's
//! upper bound, the top-k *set* is provably final; pairwise gaps
//! certify the *order*. Early epochs certify long before
//! `residual < τ`, which is what `stop_when_topk_certified`-style
//! early termination ([`solve_certified_sharded`]) cashes in.
//!
//! Tracking is incremental, not a per-check O(n) rescan: each shard
//! keeps a candidate pool ([`HeadList`]) plus an **entry floor**; the
//! push hot path (`add_r`) appends a hit whenever a row's `p + r`
//! crosses the floor (a settle leaves `p + r` unchanged and the
//! per-shard uniform share is row-constant, so no promotion can sneak
//! past). A check drains hits, re-reads pool centers, and runs a
//! tournament merge across shards — O(pool + hits + shards). Rows that
//! never crossed the floor are bounded wholesale by `floor + max
//! pending share`, so their upper bounds need no per-row work. Under a
//! personalization vector ([`super::Personalization`]) the pending-`v`
//! share is *not* row-constant: pool members fold their exact per-row
//! weight `rv·v_i/Σv` into the center, and the wholesale bound adds the
//! worst case `rv⁺·vmax/Σv` — still sound, merely conservative while
//! pending `v`-mass is large (it flushes on the first drain). Wholesale
//! state moves (bounds migration, gather, node arrivals) bump a
//! generation stamp and force one full rescan.

use std::sync::atomic::{AtomicU64, Ordering};

use super::delta::DeltaGraph;
use super::pers::Personalization;
use super::push::PushState;
use super::shard::{PushShard, ShardedPush};
use crate::obs::{EventKind, MONITOR_TRACK};

/// Process-unique head-generation stamps: every solver instance and
/// every wholesale state move draws a fresh value, so a tracker can
/// never mistake one solver's candidate pools for another solver of
/// the same shape (e.g. the roundtrip path's per-epoch `from_state`
/// rebuilds).
static HEAD_GEN: AtomicU64 = AtomicU64::new(1);

pub(crate) fn next_head_gen() -> u64 {
    HEAD_GEN.fetch_add(1, Ordering::Relaxed)
}

/// What the caller wants certified: the head size, and whether the
/// order *within* the head must be proven too (set-only is cheaper to
/// certify — order needs every consecutive gap to clear the slack).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopKGoal {
    pub k: usize,
    pub order: bool,
}

impl TopKGoal {
    /// Candidate-pool size per shard: `k` plus head-room so the entry
    /// floor sits below the k-boundary and near-boundary churn stays
    /// tracked instead of forcing rescans.
    pub(crate) fn pool_cap(&self) -> usize {
        self.k + (self.k / 2).max(8)
    }
}

/// Outcome of one certification check.
#[derive(Debug, Clone)]
pub struct TopKCertificate {
    pub k: usize,
    /// Current head: node ids by descending center (ties id-ascending),
    /// `min(k, n)` entries. Valid whether or not certification fired —
    /// it is the best current estimate of the top-k set.
    pub head: Vec<u32>,
    /// The head *set* is provably the true top-k set.
    pub set_certified: bool,
    /// Additionally, the order within the head is provably final.
    pub order_certified: bool,
    /// Worst lower bound inside the head.
    pub kth_lower: f64,
    /// Best upper bound outside the head (`-inf` when nothing is
    /// outside, e.g. `k >= n`).
    pub rest_upper: f64,
    /// One-sided interval half-widths shared by every node.
    pub slack_plus: f64,
    pub slack_minus: f64,
}

impl TopKCertificate {
    /// Did this check satisfy `goal` (set, plus order when asked)?
    pub fn certified(&self, order: bool) -> bool {
        self.set_certified && (!order || self.order_certified)
    }

    /// Certification margin `kth_lower − rest_upper`: how much true
    /// ranks could still move without changing the certified set.
    pub fn margin(&self) -> f64 {
        self.kth_lower - self.rest_upper
    }
}

/// One shard's contribution to a certification check. The threaded
/// backend publishes these to its monitor; the sequential tracker
/// builds them in place.
#[derive(Debug, Clone)]
pub(crate) struct ShardHeadFrame {
    /// (global node id, center `p + r + uni/n + pv·v_i/Σv`) for every
    /// pool member.
    pub entries: Vec<(u32, f64)>,
    /// Center upper bound for every row *not* in `entries`
    /// (`-inf` when the pool covers the whole shard).
    pub rest_bound: f64,
    /// Located-residual split (materialized r plus the shard's uniform
    /// and personalization shares), α/(1−α)-weighted in the slack.
    pub r_plus: f64,
    pub r_minus: f64,
    /// Unlocated residual split (outboxes, pending uniform broadcasts),
    /// 1/(1−α)-weighted — its t=0 landing spot is unknown.
    pub unk_plus: f64,
    pub unk_minus: f64,
}

/// Tournament merge + interval test over per-shard frames.
pub(crate) fn certify_frames(frames: &[ShardHeadFrame], k: usize, alpha: f64) -> TopKCertificate {
    let (mut rp, mut rm, mut up, mut um) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for f in frames {
        rp += f.r_plus;
        rm += f.r_minus;
        up += f.unk_plus;
        um += f.unk_minus;
    }
    // the threaded monitor feeds incremental tallies (the exact checks
    // recompute first), so tolerate float-accumulation drift here
    debug_assert!(rp >= -1e-6 && rm >= -1e-6 && up >= -1e-6 && um >= -1e-6);
    let w = 1.0 / (1.0 - alpha);
    let slack_plus = alpha * w * rp.max(0.0) + w * up.max(0.0);
    let slack_minus = alpha * w * rm.max(0.0) + w * um.max(0.0);

    if k == 0 {
        // the empty set is exactly the top-0 set of anything
        return TopKCertificate {
            k,
            head: Vec::new(),
            set_certified: true,
            order_certified: true,
            kth_lower: f64::INFINITY,
            rest_upper: f64::NEG_INFINITY,
            slack_plus,
            slack_minus,
        };
    }

    let mut all: Vec<(u32, f64)> = frames.iter().flat_map(|f| f.entries.iter().copied()).collect();
    all.sort_by(|a, b| {
        b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
    });
    let head_len = k.min(all.len());
    let head: Vec<u32> = all[..head_len].iter().map(|&(id, _)| id).collect();

    let mut rest_center = f64::NEG_INFINITY;
    for &(_, c) in &all[head_len..] {
        rest_center = rest_center.max(c);
    }
    for f in frames {
        rest_center = rest_center.max(f.rest_bound);
    }
    let rest_upper = if rest_center == f64::NEG_INFINITY {
        f64::NEG_INFINITY
    } else {
        rest_center + slack_plus
    };
    let kth_lower = if head_len == 0 {
        f64::INFINITY // no live rows at all: vacuously above the (empty) rest
    } else {
        all[head_len - 1].1 - slack_minus
    };
    // a short head is only the true top-k when nothing exists outside
    // it (fewer than k live rows); pools sized >= k guarantee a full
    // head otherwise
    let set_certified = if head_len < k {
        rest_upper == f64::NEG_INFINITY
    } else {
        kth_lower > rest_upper
    };
    let mut order_certified = set_certified;
    for pair in all[..head_len].windows(2) {
        if pair[0].1 - slack_minus <= pair[1].1 + slack_plus {
            order_certified = false;
            break;
        }
    }
    TopKCertificate {
        k,
        head,
        set_certified,
        order_certified,
        kth_lower,
        rest_upper,
        slack_plus,
        slack_minus,
    }
}

/// One shard's (or the global state's) candidate pool: the locally hot
/// rows by `p + r`, refreshed from the solver's hit stream.
#[derive(Debug, Clone)]
pub(crate) struct HeadList {
    /// Tracked local rows, id-ascending.
    pool: Vec<u32>,
    /// `p + r` floor in effect since the last refresh. `+inf` = never
    /// attached (full scan due); `-inf` = the pool covers every row.
    floor: f64,
    cap: usize,
}

impl HeadList {
    pub(crate) fn new(cap: usize) -> HeadList {
        HeadList { pool: Vec::new(), floor: f64::INFINITY, cap: cap.max(1) }
    }

    /// Refresh the pool against the current `(p, r)` slices, draining
    /// `hits` and re-arming `head_floor` for the next interval.
    /// Returns `(pool members with their p+r scores, p+r upper bound
    /// for rows outside the pool)` — the bound is what keeps untracked
    /// rows sound: they never crossed the floor that was armed while
    /// the hits accumulated.
    fn refresh(
        &mut self,
        p: &[f64],
        r: &[f64],
        hits: &mut Vec<u32>,
        head_floor: &mut f64,
    ) -> (Vec<(u32, f64)>, f64) {
        let bs = p.len();
        let full = self.floor == f64::INFINITY;
        if full {
            hits.clear();
            self.pool = (0..bs as u32).collect();
        } else if !hits.is_empty() {
            hits.sort_unstable();
            hits.dedup();
            let mut merged = Vec::with_capacity(self.pool.len() + hits.len());
            let (mut i, mut j) = (0usize, 0usize);
            while i < self.pool.len() || j < hits.len() {
                let a = self.pool.get(i).copied().unwrap_or(u32::MAX);
                let b = hits.get(j).copied().unwrap_or(u32::MAX);
                merged.push(a.min(b));
                i += (a <= b) as usize;
                j += (b <= a) as usize;
            }
            hits.clear();
            self.pool = merged;
        }
        debug_assert!(self.pool.iter().all(|&t| (t as usize) < bs));

        let mut scored: Vec<(u32, f64)> =
            self.pool.iter().map(|&t| (t, p[t as usize] + r[t as usize])).collect();
        let floor_used = self.floor;
        let mut dropped_bound = f64::NEG_INFINITY;
        if scored.len() > self.cap {
            scored.select_nth_unstable_by(self.cap - 1, |a, b| {
                b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
            });
            for &(_, s) in &scored[self.cap..] {
                dropped_bound = dropped_bound.max(s);
            }
            scored.truncate(self.cap);
        }
        let covers_all = scored.len() == bs;
        let new_floor = if covers_all {
            f64::NEG_INFINITY
        } else {
            scored.iter().map(|&(_, s)| s).fold(f64::INFINITY, f64::min)
        };
        // rows outside the pool: dropped ones sit at or below the kept
        // minimum *now*; never-tracked ones stayed under the armed
        // floor the whole interval (a first attach scanned everything,
        // so only the dropped bound applies there)
        let rest_pr = if covers_all {
            f64::NEG_INFINITY
        } else if full {
            dropped_bound.max(new_floor)
        } else {
            floor_used.max(dropped_bound)
        };
        self.floor = new_floor;
        *head_floor = if covers_all { f64::INFINITY } else { new_floor };
        self.pool = scored.iter().map(|&(t, _)| t).collect();
        self.pool.sort_unstable();
        (scored, rest_pr)
    }
}

/// Split an (Σ|x|, Σx) tally pair into its (positive, negative)
/// halves — the one place the `(l1 ± sum)/2` identity lives.
#[inline]
fn split_tally(l1: f64, sum: f64) -> (f64, f64) {
    ((l1 + sum) * 0.5, (l1 - sum) * 0.5)
}

/// Fold a signed mass into a (plus, minus) split.
#[inline]
fn fold_signed(plus: &mut f64, minus: &mut f64, m: f64) {
    if m >= 0.0 {
        *plus += m;
    } else {
        *minus -= m;
    }
}

/// Located-residual split for one shard (materialized r plus the
/// shard's replicated uniform and personalization shares) — shared by
/// [`shard_frame`] and [`interval_bounds_sharded`], so the tracker's
/// slack and its dense test mirror can never de-synchronize.
fn shard_located_split(sh: &PushShard) -> (f64, f64) {
    let (mut plus, mut minus) = split_tally(sh.r_l1, sh.r_sum);
    fold_signed(&mut plus, &mut minus, sh.uni * (sh.hi - sh.lo) as f64 / sh.n as f64);
    fold_signed(&mut plus, &mut minus, sh.pv * sh.vshare() / sh.vtotal);
    (plus, minus)
}

/// [`shard_located_split`]'s twin for the global state (the pending
/// uniform `rd` covers every row and the pending-`v` scalar `rv`
/// covers the whole support, so both fold in whole).
fn state_located_split(st: &PushState) -> (f64, f64) {
    let (mut plus, mut minus) = split_tally(st.r_l1, st.r_sum);
    fold_signed(&mut plus, &mut minus, st.rd);
    fold_signed(&mut plus, &mut minus, st.rv);
    (plus, minus)
}

/// Every shard's replicated pending scalars plus the personalization
/// vector — what [`shard_frame`] needs to score *adopted* (stolen)
/// rows under their home shard's exact shares. The threaded worker
/// path passes `None` and approximates with the local scalars (fine:
/// the monitor's stop is always re-checked exactly on settled state).
pub(crate) struct HomeShares<'a> {
    /// Each shard's pending-uniform scalar.
    pub unis: &'a [f64],
    /// Each shard's pending-`v` scalar (all zeros on the uniform path).
    pub pvs: &'a [f64],
    /// The personalization vector (`None` = global uniform run).
    pub pers: Option<&'a Personalization>,
}

/// Build a shard's frame: refresh its pool, then convert the p+r
/// domain to centers with the per-row pending shares (uniform plus,
/// under a personalization vector, the exact `pv·v_i/Σv` weight) and
/// split the residual tallies into the located / unlocated halves.
///
/// Ownership-awareness (work stealing): **lent** home rows are
/// excluded — their state lives at (and is reported by) the thief, and
/// a zero-score ghost here could otherwise duplicate a node across
/// frames. **Adopted** rows report under their *home* shard's pending
/// shares (the home's flush forwards them here): exact when `home`
/// carries every shard's scalars (the [`TopKTracker::check_sharded`]
/// path), approximated by the local uniform scalar on the tentative
/// threaded worker path (`None`) — which is fine, because the
/// monitor's stop is always re-checked exactly on the settled state.
pub(crate) fn shard_frame(
    head: &mut HeadList,
    sh: &mut PushShard,
    home: Option<&HomeShares<'_>>,
) -> ShardHeadFrame {
    let nf = sh.n as f64;
    let us = sh.uni / nf;
    let vt = sh.vtotal;
    let bs = sh.home_size();
    // upper bound on any local row's pending share: the uniform part is
    // row-constant, the `v` part is bounded by the largest home weight;
    // untracked adopted rows sit under rest_bound, whose share is their
    // home's scalars (bounded by the global vmax)
    let mut share_max = us + sh.pv.max(0.0) * sh.vmax_local() / vt;
    if let Some(hs) = home {
        let vmax = hs.pers.map_or(0.0, |p| p.vmax());
        for &node in &sh.adopted {
            let h = sh.part.owner_of(node as usize);
            share_max = share_max.max(hs.unis[h] / nf + hs.pvs[h].max(0.0) * vmax / vt);
        }
    }
    let (scored, rest_pr) = head.refresh(&sh.p, &sh.r, &mut sh.head_hits, &mut sh.head_floor);
    let entries = scored
        .into_iter()
        .filter(|&(t, _)| (t as usize) >= bs || sh.lent_owner(t as usize).is_none())
        .map(|(t, s)| {
            let k = t as usize;
            if k < bs {
                ((sh.lo + k) as u32, s + us + sh.pv * sh.vweight_local(k) / vt)
            } else {
                let node = sh.adopted[k - bs];
                let share = match home {
                    Some(hs) => {
                        let h = sh.part.owner_of(node as usize);
                        let w = hs.pers.map_or(0.0, |p| p.weight_of(node));
                        hs.unis[h] / nf + hs.pvs[h] * w / vt
                    }
                    None => us,
                };
                (node, s + share)
            }
        })
        .collect();
    let rest_bound =
        if rest_pr == f64::NEG_INFINITY { f64::NEG_INFINITY } else { rest_pr + share_max };
    let (r_plus, r_minus) = shard_located_split(sh);
    let (mut unk_plus, mut unk_minus) = split_tally(sh.acc_mass, sh.acc_sum);
    for (j, &u) in sh.out_uni.iter().enumerate() {
        let rows = sh.part.bounds()[j + 1] - sh.part.bounds()[j];
        fold_signed(&mut unk_plus, &mut unk_minus, u * rows as f64 / nf);
    }
    for (j, &q) in sh.out_pv.iter().enumerate() {
        fold_signed(&mut unk_plus, &mut unk_minus, q * sh.vshares[j] / vt);
    }
    ShardHeadFrame { entries, rest_bound, r_plus, r_minus, unk_plus, unk_minus }
}

/// [`shard_frame`]'s twin for the single-queue global state.
pub(crate) fn state_frame(head: &mut HeadList, st: &mut PushState) -> ShardHeadFrame {
    let us = st.rd / st.n() as f64;
    let rv = st.rv;
    let pers = st.pers.clone();
    let (vt, vmax) = pers.as_deref().map_or((1.0, 0.0), |p| (p.total(), p.vmax()));
    let (scored, rest_pr) = head.refresh(&st.p, &st.r, &mut st.head_hits, &mut st.head_floor);
    let entries = scored
        .into_iter()
        .map(|(t, s)| {
            let w = pers.as_deref().map_or(0.0, |p| p.weight_of(t));
            (t, s + us + rv * w / vt)
        })
        .collect();
    let share_max = us + rv.max(0.0) * vmax / vt;
    let rest_bound =
        if rest_pr == f64::NEG_INFINITY { f64::NEG_INFINITY } else { rest_pr + share_max };
    let (r_plus, r_minus) = state_located_split(st);
    ShardHeadFrame { entries, rest_bound, r_plus, r_minus, unk_plus: 0.0, unk_minus: 0.0 }
}

/// Incremental certified-head tracker. Bind one tracker to one solver
/// instance (state or sharded) — its candidate pools mirror that
/// solver's hit streams; the generation stamps catch wholesale state
/// moves, not solver swaps.
#[derive(Debug, Clone)]
pub struct TopKTracker {
    goal: TopKGoal,
    cap: usize,
    heads: Vec<HeadList>,
    /// (head generation, parts, n) the pools were built against.
    seen: Option<(u64, usize, usize)>,
}

impl TopKTracker {
    pub fn new(goal: TopKGoal) -> TopKTracker {
        TopKTracker { goal, cap: goal.pool_cap(), heads: Vec::new(), seen: None }
    }

    pub fn goal(&self) -> TopKGoal {
        self.goal
    }

    /// Certification check against a sharded solver. Settles outboxes
    /// (exchange) and re-tallies the residual sums exactly first, so
    /// the interval slacks carry no incremental float drift.
    pub fn check_sharded(&mut self, sp: &mut ShardedPush) -> TopKCertificate {
        sp.exchange();
        sp.residual_recompute();
        let key = (sp.head_gen(), sp.shard_count(), sp.n());
        if self.seen != Some(key) {
            self.heads = (0..sp.shard_count()).map(|_| HeadList::new(self.cap)).collect();
            self.seen = Some(key);
        }
        let alpha = sp.alpha();
        // every shard's pending scalars, so adopted (stolen) rows
        // report under their home shares exactly
        let unis: Vec<f64> = sp.shards.iter().map(|sh| sh.uni).collect();
        let pvs: Vec<f64> = sp.shards.iter().map(|sh| sh.pv).collect();
        let pers = sp.personalization().cloned();
        let home = HomeShares { unis: &unis, pvs: &pvs, pers: pers.as_deref() };
        let frames: Vec<ShardHeadFrame> = self
            .heads
            .iter_mut()
            .zip(sp.shards.iter_mut())
            .map(|(h, sh)| shard_frame(h, sh, Some(&home)))
            .collect();
        let cert = certify_frames(&frames, self.goal.k, alpha);
        if let Some(tr) = sp.trace_handle() {
            tr.record(
                MONITOR_TRACK,
                EventKind::CertCheck,
                cert.certified(self.goal.order) as u64,
                cert.margin(),
            );
        }
        cert
    }

    /// Certification check against the global single-queue state.
    pub fn check_state(&mut self, st: &mut PushState) -> TopKCertificate {
        st.recompute_r_l1();
        let key = (st.head_gen, 1usize, st.n());
        if self.seen != Some(key) {
            self.heads = vec![HeadList::new(self.cap)];
            self.seen = Some(key);
        }
        let alpha = st.alpha();
        let frame = state_frame(&mut self.heads[0], st);
        certify_frames(&[frame], self.goal.k, alpha)
    }
}

/// Outcome of a certified solve ([`solve_certified_state`] /
/// [`solve_certified_sharded`]).
#[derive(Debug, Clone)]
pub struct TopKSolveStats {
    /// Pushes spent by this call.
    pub pushes: u64,
    /// Pushes spent when certification first held (`Some(0)` = the
    /// warm-started head was already certified; `None` = never
    /// certified, e.g. a tie at the boundary).
    pub pushes_to_cert: Option<u64>,
    /// Whether the full `residual < tol` convergence was reached (false
    /// under `stop_at_cert` early exit or budget exhaustion).
    pub converged: bool,
    pub residual: f64,
    /// The final certificate (head reflects the exit state).
    pub cert: TopKCertificate,
}

/// Floor on pushes between certification checks; the effective chunk
/// scales with the node count ([`cert_chunk`]) because each check pays
/// an O(n) exact re-tally — a fixed chunk would drown a large graph's
/// solve in measurement overhead.
const CERT_CHUNK: u64 = 4096;

/// Pushes between certification checks for an `n`-node solver: large
/// enough that the O(n) check amortizes, small enough that early
/// certification is caught early.
fn cert_chunk(n: usize) -> u64 {
    CERT_CHUNK.max(n as u64 / 8)
}

/// Drive [`PushState::solve`] in chunks with certification checks
/// between them; with `stop_at_cert` the solve ends as soon as the
/// goal is certified (`stop_when_topk_certified` semantics), otherwise
/// it runs to `tol` and reports where certification first held.
pub fn solve_certified_state(
    st: &mut PushState,
    g: &DeltaGraph,
    tracker: &mut TopKTracker,
    tol: f64,
    max_pushes: u64,
    stop_at_cert: bool,
) -> TopKSolveStats {
    let order = tracker.goal().order;
    let chunk = cert_chunk(st.n());
    let mut pushes = 0u64;
    let mut cert = tracker.check_state(st);
    let mut pushes_to_cert = if cert.certified(order) { Some(0) } else { None };
    let (converged, residual) = loop {
        if stop_at_cert && pushes_to_cert.is_some() {
            break (st.residual_l1() < tol, st.residual_l1());
        }
        let remaining = max_pushes.saturating_sub(pushes);
        if remaining == 0 {
            break (false, st.residual_l1());
        }
        let stats = st.solve(g, tol, chunk.min(remaining));
        pushes += stats.pushes;
        if pushes_to_cert.is_none() || stats.converged {
            cert = tracker.check_state(st);
            if pushes_to_cert.is_none() && cert.certified(order) {
                pushes_to_cert = Some(pushes);
            }
        }
        if stats.converged {
            break (true, stats.residual);
        }
        if stats.pushes == 0 {
            // no progress and not converged: bail rather than spin
            break (false, stats.residual);
        }
    };
    TopKSolveStats { pushes, pushes_to_cert, converged, residual, cert }
}

/// [`solve_certified_state`]'s twin over the deterministic sharded
/// superstep solver.
pub fn solve_certified_sharded(
    sp: &mut ShardedPush,
    g: &DeltaGraph,
    tracker: &mut TopKTracker,
    tol: f64,
    max_pushes: u64,
    stop_at_cert: bool,
) -> TopKSolveStats {
    let order = tracker.goal().order;
    let chunk = cert_chunk(sp.n());
    let mut pushes = 0u64;
    let mut cert = tracker.check_sharded(sp);
    let mut pushes_to_cert = if cert.certified(order) { Some(0) } else { None };
    let (converged, residual) = loop {
        if stop_at_cert && pushes_to_cert.is_some() {
            let r = sp.residual_exact();
            break (r < tol, r);
        }
        let remaining = max_pushes.saturating_sub(pushes);
        if remaining == 0 {
            break (false, sp.residual_exact());
        }
        let stats = sp.solve(g, tol, chunk.min(remaining));
        pushes += stats.pushes;
        if pushes_to_cert.is_none() || stats.converged {
            cert = tracker.check_sharded(sp);
            if pushes_to_cert.is_none() && cert.certified(order) {
                pushes_to_cert = Some(pushes);
            }
        }
        if stats.converged {
            break (true, stats.residual);
        }
        if stats.pushes == 0 {
            break (false, stats.residual);
        }
    };
    TopKSolveStats { pushes, pushes_to_cert, converged, residual, cert }
}

/// Per-node certified enclosures `[lo_i, hi_i] ∋ x*_i` over a sharded
/// solver — O(n), the dense mirror of what [`TopKTracker`] evaluates
/// lazily. Test suites cross-check these against a converged reference
/// at every superstep; they are also the right tool for ad-hoc "how
/// wrong can this rank still be" queries.
pub fn interval_bounds_sharded(sp: &mut ShardedPush) -> Vec<(f64, f64)> {
    sp.exchange();
    sp.residual_recompute();
    let alpha = sp.alpha();
    let w = 1.0 / (1.0 - alpha);
    let (mut rp, mut rm) = (0.0f64, 0.0f64);
    for sh in &sp.shards {
        let (plus, minus) = shard_located_split(sh);
        rp += plus;
        rm += minus;
    }
    let (sp_up, sp_dn) = (alpha * w * rp, alpha * w * rm);
    let unis: Vec<f64> = sp.shards.iter().map(|sh| sh.uni).collect();
    let pvs: Vec<f64> = sp.shards.iter().map(|sh| sh.pv).collect();
    let pers = sp.personalization().cloned();
    let wof = |t: u32| pers.as_deref().map_or(0.0, |p| p.weight_of(t));
    let mut out = vec![(0.0, 0.0); sp.n()];
    for sh in &sp.shards {
        let nf = sh.n as f64;
        let us = sh.uni / nf;
        let vt = sh.vtotal;
        let bs = sh.home_size();
        for k in 0..bs {
            if sh.lent_owner(k).is_some() {
                continue; // the owner's overflow slot is authoritative
            }
            let c = sh.p[k] + sh.r[k] + us + sh.pv * sh.vweight_local(k) / vt;
            out[sh.lo + k] = (c - sp_dn, c + sp_up);
        }
        // stolen rows: state lives here, the pending shares still
        // accrue at the home shard (its flushes forward them) — center
        // with the home's scalars
        for (slot, &node) in sh.adopted.iter().enumerate() {
            let node = node as usize;
            let h = sh.part.owner_of(node);
            let share = unis[h] / nf + pvs[h] * wof(node as u32) / vt;
            let c = sh.p[bs + slot] + sh.r[bs + slot] + share;
            out[node] = (c - sp_dn, c + sp_up);
        }
    }
    out
}

/// [`interval_bounds_sharded`]'s twin for the global state.
pub fn interval_bounds_state(st: &mut PushState) -> Vec<(f64, f64)> {
    st.recompute_r_l1();
    let alpha = st.alpha();
    let w = 1.0 / (1.0 - alpha);
    let (rp, rm) = state_located_split(st);
    let (up, dn) = (alpha * w * rp, alpha * w * rm);
    let us = st.rd / st.n() as f64;
    let rv = st.rv;
    let pers = st.pers.clone();
    let vt = pers.as_deref().map_or(1.0, |p| p.total());
    (0..st.n())
        .map(|i| {
            let w_i = pers.as_deref().map_or(0.0, |p| p.weight_of(i as u32));
            let c = st.p[i] + st.r[i] + us + rv * w_i / vt;
            (c - dn, c + up)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, EdgeList};
    use crate::stream::{power_method_f64, UpdateBatch};
    use crate::util::Rng;

    fn web(n: usize, seed: u64) -> DeltaGraph {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        DeltaGraph::from_edgelist(&el)
    }

    fn exact_topk(x: &[f64], k: usize) -> Vec<u32> {
        crate::pagerank::top_k_ids(x, k)
    }

    fn set_eq(a: &[u32], b: &[u32]) -> bool {
        let mut a = a.to_vec();
        let mut b = b.to_vec();
        a.sort_unstable();
        b.sort_unstable();
        a == b
    }

    #[test]
    fn intervals_enclose_truth_at_every_superstep() {
        // the debug-assert-style cross-check, as a test: the certified
        // enclosure must contain the converged reference at EVERY chunk
        // boundary of a cold solve — not just at the end
        let g = web(800, 101);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        for shards in [1usize, 3] {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            loop {
                let bounds = interval_bounds_sharded(&mut sp);
                for (i, &(lo, hi)) in bounds.iter().enumerate() {
                    assert!(
                        lo - 1e-11 <= xref[i] && xref[i] <= hi + 1e-11,
                        "shards {shards}: x*[{i}] = {} outside [{lo}, {hi}]",
                        xref[i]
                    );
                }
                let st = sp.solve(&g, 1e-11, 512);
                if st.converged {
                    break;
                }
            }
        }
    }

    #[test]
    fn intervals_enclose_truth_after_batches_and_dangling_flips() {
        // post-apply_batch states (the injected residual is signed) and
        // dangling transitions must keep the enclosure sound
        let el = EdgeList::from_edges(6, vec![(0, 1), (0, 2), (1, 2), (2, 0), (4, 5)]).unwrap();
        let mut g = DeltaGraph::from_edgelist(&el);
        let mut st = PushState::new(g.n(), 0.85);
        st.begin_epoch();
        st.solve(&g, 1e-13, u64::MAX);
        // node 1 goes dangling, node 3 stops being dangling, +1 arrival
        let delta = g
            .apply(&UpdateBatch {
                new_nodes: 1,
                insert: vec![(3, 0), (6, 2)],
                remove: vec![(1, 2)],
            })
            .unwrap();
        st.begin_epoch();
        st.apply_batch(&g, &delta);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-14, 100_000);
        loop {
            let bounds = interval_bounds_state(&mut st);
            for (i, &(lo, hi)) in bounds.iter().enumerate() {
                assert!(
                    lo - 1e-12 <= xref[i] && xref[i] <= hi + 1e-12,
                    "x*[{i}] = {} outside [{lo}, {hi}]",
                    xref[i]
                );
            }
            if st.solve(&g, 1e-13, 64).converged {
                break;
            }
        }
    }

    #[test]
    fn certified_set_is_sound_when_it_fires() {
        let g = web(1_500, 102);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        for shards in [1usize, 4] {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            let mut tr = TopKTracker::new(TopKGoal { k: 20, order: false });
            let st = solve_certified_sharded(&mut sp, &g, &mut tr, 1e-10, u64::MAX, true);
            let fired = st.pushes_to_cert.expect("power-law web must certify k=20");
            assert!(
                st.cert.set_certified,
                "shards {shards}: exit cert must hold under stop_at_cert"
            );
            assert!(
                set_eq(&st.cert.head, &exact_topk(&xref, 20)),
                "shards {shards}: certified set != true top-20"
            );
            // and it certified strictly before full convergence
            let mut full = ShardedPush::new(&g, 0.85, shards);
            let fs = full.solve(&g, 1e-10, u64::MAX);
            assert!(fs.converged);
            assert!(
                fired < fs.pushes,
                "shards {shards}: cert at {fired} pushes vs convergence {}",
                fs.pushes
            );
        }
    }

    #[test]
    fn ordered_certification_needs_more_work_than_set() {
        let g = web(1_200, 103);
        let run = |order: bool| {
            let mut sp = ShardedPush::new(&g, 0.85, 2);
            let mut tr = TopKTracker::new(TopKGoal { k: 10, order });
            solve_certified_sharded(&mut sp, &g, &mut tr, 1e-11, u64::MAX, true)
        };
        let set_only = run(false);
        let ordered = run(true);
        let (a, b) = (set_only.pushes_to_cert.unwrap(), ordered.pushes_to_cert.unwrap());
        assert!(a <= b, "set cert {a} must not cost more than order cert {b}");
        assert!(ordered.cert.order_certified);
        // the ordered head must match the reference ORDER, not just set
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        assert_eq!(ordered.cert.head, exact_topk(&xref, 10));
    }

    #[test]
    fn intervals_and_certificates_stay_sound_across_steals() {
        // move ownership mid-solve, including head candidates: the
        // per-node enclosures must still contain the truth and a fired
        // certificate must still name the true top-k
        let g = web(1_000, 110);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let mut tr = TopKTracker::new(TopKGoal { k: 12, order: false });
        let mut round = 0usize;
        loop {
            let bounds = interval_bounds_sharded(&mut sp);
            for (i, &(lo, hi)) in bounds.iter().enumerate() {
                assert!(
                    lo - 1e-11 <= xref[i] && xref[i] <= hi + 1e-11,
                    "round {round}: x*[{i}] = {} outside [{lo}, {hi}]",
                    xref[i]
                );
            }
            let cert = tr.check_sharded(&mut sp);
            // the head must never contain a node twice (a stolen row
            // reported by both its home and its owner would)
            let mut ids = cert.head.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), cert.head.len(), "round {round}: duplicate in head");
            if cert.set_certified {
                let mut want = exact_topk(&xref, 12);
                want.sort_unstable();
                assert_eq!(ids, want, "round {round}: certified set wrong mid-steal");
            }
            let st = sp.solve(&g, 1e-11, 600);
            if st.converged {
                break;
            }
            // steal between every chunk, rotating pairs
            let v = round % 4;
            let t = (round + 1) % 4;
            sp.steal_rows(v, t, 8);
            round += 1;
        }
        let cert = tr.check_sharded(&mut sp);
        assert!(cert.set_certified, "converged power-law web must certify k=12");
        let mut got = cert.head.clone();
        let mut want = exact_topk(&xref, 12);
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn tie_at_the_boundary_degrades_gracefully() {
        // a directed ring: every rank is exactly 1/n — no k in (0, n)
        // can ever certify, and nothing may panic or loop forever
        let n = 24usize;
        let el = EdgeList::from_edges(
            n,
            (0..n).map(|i| (i as u32, ((i + 1) % n) as u32)).collect(),
        )
        .unwrap();
        let g = DeltaGraph::from_edgelist(&el);
        let mut sp = ShardedPush::new(&g, 0.85, 3);
        let mut tr = TopKTracker::new(TopKGoal { k: 5, order: false });
        let st = solve_certified_sharded(&mut sp, &g, &mut tr, 1e-12, u64::MAX, false);
        assert!(st.converged, "ties must not block convergence");
        assert_eq!(st.pushes_to_cert, None, "a perfect tie must never certify");
        assert!(!st.cert.set_certified);
        assert_eq!(st.cert.head.len(), 5, "head estimate still reported");
    }

    #[test]
    fn k_zero_and_k_beyond_n_are_trivially_certified() {
        let g = web(60, 104);
        let mut sp = ShardedPush::new(&g, 0.85, 2);
        let mut t0 = TopKTracker::new(TopKGoal { k: 0, order: true });
        let c0 = t0.check_sharded(&mut sp);
        assert!(c0.set_certified && c0.order_certified && c0.head.is_empty());

        // k >= n: the head is "everything", certified as a set the
        // moment the pool covers all live rows
        let mut tall = TopKTracker::new(TopKGoal { k: g.n() + 10, order: false });
        let call = tall.check_sharded(&mut sp);
        assert_eq!(call.head.len(), g.n());
        assert!(call.set_certified, "rest is empty: {}", call.rest_upper);
        assert_eq!(call.rest_upper, f64::NEG_INFINITY);
    }

    #[test]
    fn mass_deletion_empties_the_head_without_panic() {
        // delete every edge: all ranks collapse to uniform; the tracker
        // must survive the epoch and report an uncertifiable head
        let mut g = web(120, 105);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let mut tr = TopKTracker::new(TopKGoal { k: 8, order: false });
        let first = solve_certified_sharded(&mut sp, &g, &mut tr, 1e-11, u64::MAX, false);
        assert!(first.converged);
        let mut batch = UpdateBatch::default();
        g.for_each_edge(|s, d| batch.remove.push((s, d)));
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        let st = solve_certified_sharded(&mut sp, &g, &mut tr, 1e-11, u64::MAX, false);
        assert!(st.converged);
        assert_eq!(st.pushes_to_cert, None, "uniform ranks cannot certify k=8");
        let ranks = sp.ranks();
        let spread = ranks.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - ranks.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 1e-9, "all-dangling graph must rank uniformly, spread {spread}");
    }

    #[test]
    fn tracker_follows_churn_across_epochs_incrementally() {
        // the tracker is attached once and fed only hits + gen bumps;
        // after N churn epochs its head must equal a from-scratch sort
        let mut g = web(900, 106);
        let mut sp = ShardedPush::new(&g, 0.85, 3);
        let mut tr = TopKTracker::new(TopKGoal { k: 12, order: false });
        solve_certified_sharded(&mut sp, &g, &mut tr, 1e-11, u64::MAX, false);
        let mut rng = Rng::new(107);
        for epoch in 0..5 {
            let n = g.n();
            let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
            for _ in 0..30 {
                batch.insert.push((rng.range(0, n + 2) as u32, rng.range(0, n) as u32));
            }
            let mut edges = Vec::new();
            g.for_each_edge(|s, d| edges.push((s, d)));
            for _ in 0..15 {
                batch.remove.push(edges[rng.range(0, edges.len())]);
            }
            let delta = g.apply(&batch).unwrap();
            sp.begin_epoch();
            sp.apply_batch(&g, &delta);
            let st = solve_certified_sharded(&mut sp, &g, &mut tr, 1e-11, u64::MAX, false);
            assert!(st.converged, "epoch {epoch}");
            let from_scratch = exact_topk(&sp.ranks(), 12);
            assert!(
                set_eq(&st.cert.head, &from_scratch),
                "epoch {epoch}: tracker head diverged from a fresh sort"
            );
            if let Some(at) = st.pushes_to_cert {
                let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
                assert!(
                    set_eq(&st.cert.head, &exact_topk(&xref, 12)),
                    "epoch {epoch}: certified at {at} pushes but set is wrong"
                );
            }
        }
    }

    #[test]
    fn ppr_intervals_and_certificates_use_personalization_shares() {
        // single-source-set PPR: mid-solve enclosures must contain the
        // personalized reference and a fired certificate must name the
        // true personalized top-k — exercising the exact per-row
        // `pv·v_i/Σv` share in pool centers and the `vmax` bound on
        // untracked rows (both are zero on every other test's path)
        use crate::stream::{power_method_pers, Personalization};
        use std::sync::Arc;
        let g = web(1_000, 111);
        let pers = Arc::new(Personalization::sources(&[3, 17, 42]).unwrap());
        let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-13, 100_000);
        for shards in [1usize, 3] {
            let mut sp = ShardedPush::new_personalized(&g, 0.85, shards, Arc::clone(&pers));
            let mut tr = TopKTracker::new(TopKGoal { k: 8, order: false });
            loop {
                let bounds = interval_bounds_sharded(&mut sp);
                for (i, &(lo, hi)) in bounds.iter().enumerate() {
                    assert!(
                        lo - 1e-11 <= xref[i] && xref[i] <= hi + 1e-11,
                        "shards {shards}: ppr x*[{i}] = {} outside [{lo}, {hi}]",
                        xref[i]
                    );
                }
                let cert = tr.check_sharded(&mut sp);
                if cert.set_certified {
                    assert!(
                        set_eq(&cert.head, &exact_topk(&xref, 8)),
                        "shards {shards}: certified PPR set wrong mid-solve"
                    );
                }
                if sp.solve(&g, 1e-12, 400).converged {
                    break;
                }
            }
            let cert = tr.check_sharded(&mut sp);
            assert!(cert.set_certified, "shards {shards}: converged PPR must certify k=8");
            assert!(set_eq(&cert.head, &exact_topk(&xref, 8)));
        }
        // the single-queue state path agrees
        let mut st = PushState::new_personalized(g.n(), 0.85, Arc::clone(&pers));
        st.begin_epoch();
        let mut tr = TopKTracker::new(TopKGoal { k: 8, order: false });
        let stats = solve_certified_state(&mut st, &g, &mut tr, 1e-12, u64::MAX, false);
        assert!(stats.converged);
        assert!(stats.cert.set_certified, "state path: converged PPR must certify k=8");
        assert!(set_eq(&stats.cert.head, &exact_topk(&xref, 8)));
        for (i, &(lo, hi)) in interval_bounds_state(&mut st).iter().enumerate() {
            assert!(
                lo - 1e-11 <= xref[i] && xref[i] <= hi + 1e-11,
                "state path: ppr x*[{i}] = {} outside [{lo}, {hi}]",
                xref[i]
            );
        }
    }

    #[test]
    fn warm_epoch_certifies_in_a_fraction_of_convergence_pushes() {
        // the serving-path claim at unit scale: after one small churn
        // batch, certifying the head is much cheaper than re-converging
        let mut g = web(3_000, 108);
        let mut sp = ShardedPush::new(&g, 0.85, 2);
        let mut tr = TopKTracker::new(TopKGoal { k: 16, order: false });
        solve_certified_sharded(&mut sp, &g, &mut tr, 1e-10, u64::MAX, false);
        let mut cert_total = 0u64;
        let mut conv_total = 0u64;
        let mut rng = Rng::new(109);
        for _ in 0..3 {
            let n = g.n();
            let mut batch = UpdateBatch::default();
            for _ in 0..10 {
                batch.insert.push((rng.range(0, n) as u32, rng.range(0, n) as u32));
            }
            let delta = g.apply(&batch).unwrap();
            sp.begin_epoch();
            sp.apply_batch(&g, &delta);
            let st = solve_certified_sharded(&mut sp, &g, &mut tr, 1e-10, u64::MAX, false);
            assert!(st.converged);
            cert_total += st.pushes_to_cert.expect("warm epoch must certify");
            conv_total += st.pushes;
        }
        assert!(
            cert_total <= conv_total / 2,
            "certification ({cert_total} pushes) should beat convergence ({conv_total})"
        );
    }
}
