//! The PPR serving tier: batched multi-source push over an LRU cache
//! of hot source states.
//!
//! The paper motivates PageRank as the ranking a search engine *serves*
//! (§1); personalized PageRank turns that into a per-query workload —
//! millions of users, each with their own teleport vector. This module
//! is the query tier over the personalized push machinery
//! ([`Personalization`] / [`PushState::new_personalized`]):
//!
//! * **Batched multi-source push** ([`ServeTier::query_batch`]): many
//!   queries advance in lockstep rounds. Each round, every live query
//!   proposes its hottest queued row; the other queries of the batch
//!   *piggyback* on the same row whenever their own residual there is
//!   non-negligible, so one graph-row fetch (cache-hot adjacency)
//!   settles the row for the whole batch. Queries whose source sets
//!   overlap — the realistic hot-query distribution — share most of
//!   their frontier and amortize one pass over the graph.
//! * **LRU source cache**: a solved query's [`PushState`] is kept warm,
//!   keyed by its canonical (sorted, deduplicated) source set. A repeat
//!   query re-certifies in O(head) instead of re-solving.
//! * **Incremental invalidation** ([`ServeTier::apply_batch`]): graph
//!   churn does *not* drop the cache. Each cached state absorbs the
//!   delta through [`PushState::apply_batch`], which injects exactly
//!   the residual `α(S'−S)p` the delta created — the next query on
//!   that source set warm-starts from a nearly-converged vector and
//!   spends pushes proportional to the *change*, never a cold solve.
//! * **Certified answers**: every answer carries the top-k head with
//!   the [`TopKTracker`] set-certificate evaluated on the settled
//!   state, so a served ranking is provably final, not heuristic.
//!
//! The fixed point of each cached state satisfies
//! `Σp + R/(1−α) = Σv`; everything the tier does — piggyback pushes,
//! delta injection, certification — preserves that invariant because
//! it only ever calls the push engine's own primitives.
//!
//! [`Personalization`]: super::Personalization

use std::collections::HashMap;
use std::sync::Arc;

use super::delta::{AppliedDelta, DeltaGraph};
use super::pers::Personalization;
use super::push::PushState;
use super::topk::{TopKGoal, TopKTracker};
use crate::Result;

/// Tier configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Damping factor shared by every query state.
    pub alpha: f64,
    /// Per-query residual target: an answer is returned once
    /// `‖r‖₁ + |rd| + |rv| < tol` for its state.
    pub tol: f64,
    /// Distinct source sets kept warm (0 disables caching — every
    /// query solves cold and is dropped after answering).
    pub cache_cap: usize,
    /// Head size certified per answer (0 skips head maintenance).
    pub topk: usize,
    /// Push budget per query per call (batch piggybacking counts
    /// against the state it advances). The answer stays sound when it
    /// fires — just possibly uncertified.
    pub max_pushes: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { alpha: 0.85, tol: 1e-10, cache_cap: 64, topk: 16, max_pushes: u64::MAX }
    }
}

/// Running tier counters (monotone across the tier's lifetime).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Queries answered.
    pub queries: u64,
    /// Queries served from a warm cached state.
    pub hits: u64,
    /// Queries that built a cold state.
    pub misses: u64,
    /// Cache entries dropped by LRU pressure.
    pub evictions: u64,
    /// Total pushes spent (batch rounds + finisher solves).
    pub pushes: u64,
    /// Pushes spent advancing warm (cache-hit) states.
    pub warm_pushes: u64,
    /// Pushes spent on cold builds.
    pub cold_pushes: u64,
}

impl ServeStats {
    /// Fraction of queries served warm (0 when nothing was asked yet).
    pub fn hit_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.hits as f64 / self.queries as f64
        }
    }
}

/// One served PPR answer.
#[derive(Debug, Clone)]
pub struct PprAnswer {
    /// Canonical (sorted, deduplicated) source set.
    pub sources: Vec<u32>,
    /// Top-k head, descending rank (best current estimate even when
    /// uncertified; empty when `topk == 0`).
    pub head: Vec<u32>,
    /// Settled rank estimate for each head node.
    pub scores: Vec<f64>,
    /// The head *set* is provably the true personalized top-k.
    pub set_certified: bool,
    /// Residual of the answering state at return time.
    pub residual: f64,
    /// Pushes this call spent on the answering state. Duplicate source
    /// sets inside one batch share a state and report the same figure.
    pub pushes: u64,
    /// Whether the answering state came from the cache.
    pub from_cache: bool,
}

/// A cached source state: the personalized push state plus the
/// incremental head tracker bound to it (its candidate pools stay warm
/// across queries too).
struct CacheEntry {
    st: PushState,
    tracker: TopKTracker,
    last_used: u64,
}

/// The serving tier. One tier owns one evolving graph's query cache;
/// feed every epoch's delta through [`apply_batch`](Self::apply_batch)
/// to keep the cached states aligned with the graph.
pub struct ServeTier {
    opts: ServeOptions,
    cache: HashMap<Vec<u32>, CacheEntry>,
    /// LRU clock (bumped once per `query_batch` call).
    tick: u64,
    stats: ServeStats,
}

/// In-flight work for one distinct source set of a batch.
struct WorkItem {
    key: Vec<u32>,
    entry: CacheEntry,
    from_cache: bool,
    pushes: u64,
}

impl ServeTier {
    pub fn new(opts: ServeOptions) -> ServeTier {
        assert!(opts.tol > 0.0, "tol must be positive");
        ServeTier { opts, cache: HashMap::new(), tick: 0, stats: ServeStats::default() }
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Warm source sets currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Absorb one epoch's graph delta into every cached state — the
    /// *incremental* invalidation contract: only the residual the delta
    /// created is injected; no state is dropped or rebuilt.
    pub fn apply_batch(&mut self, g: &DeltaGraph, delta: &AppliedDelta) {
        for entry in self.cache.values_mut() {
            entry.st.begin_epoch();
            entry.st.apply_batch(g, delta);
        }
    }

    /// Answer one query (see [`query_batch`](Self::query_batch)).
    pub fn query(&mut self, g: &DeltaGraph, sources: &[u32]) -> Result<PprAnswer> {
        let mut v = self.query_batch(g, &[sources.to_vec()])?;
        Ok(v.pop().expect("one query in, one answer out"))
    }

    /// Answer a batch of PPR queries, amortizing graph-row fetches
    /// across the batch (see the module docs for the round protocol).
    /// Answers come back in query order; duplicate source sets share
    /// one state. A degenerate query rejects the whole batch *before*
    /// any state is touched.
    pub fn query_batch(&mut self, g: &DeltaGraph, queries: &[Vec<u32>]) -> Result<Vec<PprAnswer>> {
        // Validate and canonicalize everything up front: an error after
        // `cache.remove` would leak warm states.
        let mut keys: Vec<Vec<u32>> = Vec::with_capacity(queries.len());
        for q in queries {
            let mut key = q.clone();
            key.sort_unstable();
            key.dedup();
            anyhow::ensure!(!key.is_empty(), "PPR query needs at least one source");
            anyhow::ensure!(
                (*key.last().unwrap() as usize) < g.n(),
                "source {} out of range (n = {})",
                key.last().unwrap(),
                g.n()
            );
            keys.push(key);
        }

        let mut work: Vec<WorkItem> = Vec::new();
        let mut slots: HashMap<Vec<u32>, usize> = HashMap::new();
        let mut wis: Vec<usize> = Vec::with_capacity(queries.len());
        for key in keys {
            let wi = match slots.get(&key) {
                Some(&wi) => {
                    // duplicate inside the batch: shares the state, and
                    // it is warm by construction for the second asker
                    self.stats.hits += 1;
                    wi
                }
                None => {
                    let (entry, from_cache) = match self.cache.remove(&key) {
                        Some(e) => {
                            self.stats.hits += 1;
                            (e, true)
                        }
                        None => {
                            self.stats.misses += 1;
                            let pers = Arc::new(Personalization::sources(&key)?);
                            let mut st =
                                PushState::new_personalized(g.n(), self.opts.alpha, pers);
                            st.begin_epoch();
                            let tracker = TopKTracker::new(TopKGoal {
                                k: self.opts.topk,
                                order: false,
                            });
                            (CacheEntry { st, tracker, last_used: 0 }, false)
                        }
                    };
                    work.push(WorkItem { key: key.clone(), entry, from_cache, pushes: 0 });
                    slots.insert(key, work.len() - 1);
                    work.len() - 1
                }
            };
            wis.push(wi);
            self.stats.queries += 1;
        }

        // Batched push rounds: each live query proposes its hottest
        // row; the rest of the batch piggybacks while the row is hot.
        // Any positive piggyback threshold preserves correctness (each
        // state's own proposals drive it to tol; piggybacking only
        // front-loads work it would do anyway) — one uniform share of
        // the tolerance keeps the no-op rate low.
        let tol = self.opts.tol;
        let thresh = tol / g.n().max(1) as f64;
        let mut active: Vec<usize> = (0..work.len()).collect();
        loop {
            active.retain(|&qi| {
                work[qi].pushes < self.opts.max_pushes && work[qi].entry.st.residual_l1() >= tol
            });
            let mut progressed = false;
            for idx in 0..active.len() {
                let qi = active[idx];
                let Some(u) = work[qi].entry.st.pop_hottest() else { continue };
                progressed = true; // even a stale pop drains the queue
                for (qj, w) in work.iter_mut().enumerate() {
                    if w.pushes >= self.opts.max_pushes {
                        continue; // budget-exhausted states stop riding along
                    }
                    let r = w.entry.st.residual_at(u);
                    // the proposer settles its row whenever it still
                    // carries mass (a piggyback may have zeroed it);
                    // everyone else piggybacks above the threshold
                    if (qj == qi && r != 0.0) || (qj != qi && r.abs() >= thresh) {
                        w.entry.st.push_at(g, u);
                        w.pushes += 1;
                    }
                }
            }
            if !progressed {
                break;
            }
        }

        // Finisher: flush pending scalars, confirm convergence against
        // an exact tally, and certify the head on the settled state.
        let mut answers_by_wi: Vec<PprAnswer> = Vec::with_capacity(work.len());
        for w in work.iter_mut() {
            let remaining = self.opts.max_pushes.saturating_sub(w.pushes).max(1);
            let solved = w.entry.st.solve(g, tol, remaining);
            w.pushes += solved.pushes;
            w.entry.st.settle_pending();
            let cert = w.entry.tracker.check_state(&mut w.entry.st);
            let scores: Vec<f64> =
                cert.head.iter().map(|&t| w.entry.st.ranks()[t as usize]).collect();
            self.stats.pushes += w.pushes;
            if w.from_cache {
                self.stats.warm_pushes += w.pushes;
            } else {
                self.stats.cold_pushes += w.pushes;
            }
            answers_by_wi.push(PprAnswer {
                sources: w.key.clone(),
                head: cert.head,
                scores,
                set_certified: cert.set_certified,
                residual: w.entry.st.residual_l1(),
                pushes: w.pushes,
                from_cache: w.from_cache,
            });
        }

        // Reinsert and trim to capacity (evict least-recently-used).
        self.tick += 1;
        for w in work {
            let mut entry = w.entry;
            entry.last_used = self.tick;
            self.cache.insert(w.key, entry);
        }
        while self.cache.len() > self.opts.cache_cap {
            let victim = self
                .cache
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("cache over capacity implies non-empty");
            self.cache.remove(&victim);
            self.stats.evictions += 1;
        }

        Ok(wis.into_iter().map(|wi| answers_by_wi[wi].clone()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::stream::{power_method_pers, UpdateBatch};
    use crate::util::Rng;

    fn web(n: usize, seed: u64) -> DeltaGraph {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        DeltaGraph::from_edgelist(&el)
    }

    fn opts(tol: f64, cap: usize, k: usize) -> ServeOptions {
        ServeOptions { alpha: 0.85, tol, cache_cap: cap, topk: k, max_pushes: u64::MAX }
    }

    #[test]
    fn repeat_query_is_a_hit_and_nearly_free() {
        let g = web(600, 210);
        let mut tier = ServeTier::new(opts(1e-10, 8, 10));
        let a = tier.query(&g, &[5, 11]).unwrap();
        assert!(!a.from_cache && a.pushes > 0);
        let b = tier.query(&g, &[11, 5, 11]).unwrap(); // canonicalizes to the same key
        assert!(b.from_cache, "second ask must hit the cache");
        assert_eq!(b.pushes, 0, "a converged cached state re-certifies without pushing");
        assert_eq!(a.head, b.head);
        assert_eq!(tier.stats().hits, 1);
        assert_eq!(tier.stats().misses, 1);
        assert!((tier.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn answers_match_the_personalized_reference() {
        let g = web(800, 211);
        let mut tier = ServeTier::new(opts(1e-12, 8, 12));
        let batch: Vec<Vec<u32>> = vec![vec![3], vec![40, 41], vec![3, 40]];
        let answers = tier.query_batch(&g, &batch).unwrap();
        for (q, a) in batch.iter().zip(&answers) {
            let pers = Personalization::sources(q).unwrap();
            let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-13, 100_000);
            assert!(a.set_certified, "sources {q:?} must certify on a converged state");
            let mut got = a.head.clone();
            let mut want = crate::pagerank::top_k_ids(&xref, 12);
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "sources {q:?}: served head != reference top-12");
            for (&t, &s) in a.head.iter().zip(&a.scores) {
                assert!(
                    (s - xref[t as usize]).abs() < 1e-9,
                    "sources {q:?}: score for node {t} off: {s} vs {}",
                    xref[t as usize]
                );
            }
        }
    }

    #[test]
    fn churn_invalidates_incrementally_and_stays_correct() {
        let mut g = web(700, 212);
        let mut tier = ServeTier::new(opts(1e-11, 8, 10));
        let cold = tier.query(&g, &[7]).unwrap();
        let mut rng = Rng::new(213);
        for round in 0..5 {
            let n = g.n();
            let mut batch = UpdateBatch::default();
            for _ in 0..12 {
                batch.insert.push((rng.range(0, n) as u32, rng.range(0, n) as u32));
            }
            let delta = g.apply(&batch).unwrap();
            tier.apply_batch(&g, &delta);
            let warm = tier.query(&g, &[7]).unwrap();
            assert!(warm.from_cache, "round {round}: churn must not drop the cache");
            // warm re-solve costs a fraction of the cold build
            assert!(
                warm.pushes < cold.pushes / 2,
                "round {round}: warm {} vs cold {} pushes",
                warm.pushes,
                cold.pushes
            );
            let pers = Personalization::single_source(7);
            let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-13, 100_000);
            let mut got = warm.head.clone();
            let mut want = crate::pagerank::top_k_ids(&xref, 10);
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "round {round}: cached-then-churned head wrong");
        }
    }

    #[test]
    fn lru_evicts_the_coldest_source_set() {
        let g = web(300, 214);
        let mut tier = ServeTier::new(opts(1e-9, 2, 4));
        tier.query(&g, &[1]).unwrap();
        tier.query(&g, &[2]).unwrap();
        tier.query(&g, &[1]).unwrap(); // refresh [1]
        tier.query(&g, &[3]).unwrap(); // must evict [2]
        assert_eq!(tier.cache_len(), 2);
        assert_eq!(tier.stats().evictions, 1);
        assert!(tier.query(&g, &[1]).unwrap().from_cache);
        assert!(!tier.query(&g, &[2]).unwrap().from_cache, "[2] was the LRU victim");
    }

    #[test]
    fn degenerate_queries_are_rejected() {
        let g = web(50, 215);
        let mut tier = ServeTier::new(opts(1e-9, 2, 4));
        assert!(tier.query(&g, &[]).is_err(), "empty source set");
        assert!(tier.query(&g, &[50]).is_err(), "source out of range");
        assert_eq!(tier.stats().queries, 0, "rejected queries must not count");
    }
}
