//! `DeltaGraph` — a mutable, epoch-batched overlay over the static CSR
//! pipeline.
//!
//! The crawl view of the Web is never frozen: pages arrive, links churn.
//! The paper's asynchronous premise (§1) is that synchronized global
//! recomputation is untenable at that scale; this structure supplies the
//! other half of the story — a graph that *changes between solves*.
//!
//! Representation: forward (out-edge) adjacency, sorted and
//! deduplicated per source. That is the orientation a crawler produces
//! and the one the push solver ([`super::PushState`]) walks; the static
//! analysis stack keeps using the transposed [`Csr`] obtained through
//! [`DeltaGraph::to_csr`] (the "snapshot handoff").
//!
//! Updates are applied in batches ([`UpdateBatch`]) — one batch per
//! epoch — and every apply returns an [`AppliedDelta`] recording which
//! sources changed and what their out-lists were, which is exactly the
//! information the warm-start residual injection needs
//! (`PushState::apply_batch`).

use std::collections::BTreeMap;

use crate::graph::{Csr, EdgeList, NodeId};
use crate::Result;

/// One epoch's worth of graph mutations.
///
/// Semantics of `apply`: the node set grows by `new_nodes` first (ids
/// `old_n..old_n + new_nodes`, born dangling), then `insert` edges are
/// added, then `remove` edges are deleted. Inserts of already-present
/// edges and removals of absent edges are no-ops (the adjacency is 0/1,
/// matching CSR dedup semantics).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UpdateBatch {
    pub new_nodes: usize,
    pub insert: Vec<(NodeId, NodeId)>,
    pub remove: Vec<(NodeId, NodeId)>,
}

impl UpdateBatch {
    pub fn is_empty(&self) -> bool {
        self.new_nodes == 0 && self.insert.is_empty() && self.remove.is_empty()
    }

    /// Nominal size of the batch (requested ops, before dedup).
    pub fn len(&self) -> usize {
        self.new_nodes + self.insert.len() + self.remove.len()
    }
}

/// What actually changed when a batch was applied.
#[derive(Debug, Clone)]
pub struct AppliedDelta {
    pub old_n: usize,
    pub new_n: usize,
    /// Effective (post-dedup) edge insertions / removals.
    pub inserted: usize,
    pub removed: usize,
    /// Every source whose out-edge set changed, with its *previous*
    /// out-list (sorted). Sources whose list ended up identical (an
    /// insert cancelled by a removal in the same batch) are omitted.
    pub changed_sources: Vec<(NodeId, Vec<NodeId>)>,
}

/// Mutable forward-adjacency web graph, updated in epochs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeltaGraph {
    /// Sorted, deduplicated out-neighbors per source.
    out: Vec<Vec<NodeId>>,
    /// Total edge count (Σ out-degrees).
    m: usize,
    /// Number of batches applied so far.
    epoch: u64,
}

impl DeltaGraph {
    /// Empty graph on `n` nodes (all dangling).
    pub fn new(n: usize) -> Self {
        DeltaGraph { out: vec![Vec::new(); n], m: 0, epoch: 0 }
    }

    /// Build from an edge list (duplicates collapsed, like CSR).
    pub fn from_edgelist(el: &EdgeList) -> Self {
        let mut out = vec![Vec::new(); el.n()];
        for &(s, d) in el.edges() {
            out[s as usize].push(d);
        }
        let mut m = 0;
        for l in out.iter_mut() {
            l.sort_unstable();
            l.dedup();
            m += l.len();
        }
        DeltaGraph { out, m, epoch: 0 }
    }

    pub fn n(&self) -> usize {
        self.out.len()
    }

    /// Deduplicated edge count.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Batches applied so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    #[inline]
    pub fn outdeg(&self, u: usize) -> usize {
        self.out[u].len()
    }

    /// Sorted out-neighbors of `u`.
    #[inline]
    pub fn out(&self, u: usize) -> &[NodeId] {
        &self.out[u]
    }

    #[inline]
    pub fn is_dangling(&self, u: usize) -> bool {
        self.out[u].is_empty()
    }

    pub fn dangling_count(&self) -> usize {
        self.out.iter().filter(|l| l.is_empty()).count()
    }

    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.out[u as usize].binary_search(&v).is_ok()
    }

    /// Visit every edge (source, target), sources in order.
    pub fn for_each_edge(&self, mut f: impl FnMut(NodeId, NodeId)) {
        for (u, l) in self.out.iter().enumerate() {
            for &v in l {
                f(u as NodeId, v);
            }
        }
    }

    /// Apply one batch; returns the effective delta (see
    /// [`AppliedDelta`]). Fails on out-of-bounds endpoints — the graph
    /// is left untouched in that case.
    pub fn apply(&mut self, batch: &UpdateBatch) -> Result<AppliedDelta> {
        let old_n = self.n();
        let new_n = old_n + batch.new_nodes;
        for &(s, d) in batch.insert.iter().chain(&batch.remove) {
            if s as usize >= new_n || d as usize >= new_n {
                anyhow::bail!(
                    "update edge ({s}, {d}) out of bounds for n={new_n} \
                     (old n {old_n} + {} arrivals)",
                    batch.new_nodes
                );
            }
        }
        self.out.resize(new_n, Vec::new());

        // old out-lists, captured lazily the first time a source changes
        let mut old_lists: BTreeMap<NodeId, Vec<NodeId>> = BTreeMap::new();
        let mut inserted = 0usize;
        let mut removed = 0usize;
        for &(s, d) in &batch.insert {
            let l = &mut self.out[s as usize];
            if let Err(pos) = l.binary_search(&d) {
                old_lists.entry(s).or_insert_with(|| l.clone());
                l.insert(pos, d);
                self.m += 1;
                inserted += 1;
            }
        }
        for &(s, d) in &batch.remove {
            let l = &mut self.out[s as usize];
            if let Ok(pos) = l.binary_search(&d) {
                old_lists.entry(s).or_insert_with(|| l.clone());
                l.remove(pos);
                self.m -= 1;
                removed += 1;
            }
        }

        // drop sources whose list round-tripped back to its old value
        let changed_sources: Vec<(NodeId, Vec<NodeId>)> = old_lists
            .into_iter()
            .filter(|(s, old)| &self.out[*s as usize] != old)
            .collect();

        self.epoch += 1;
        Ok(AppliedDelta { old_n, new_n, inserted, removed, changed_sources })
    }

    /// Materialize as an edge list (sorted by source, then target).
    pub fn to_edgelist(&self) -> EdgeList {
        let mut el = EdgeList::with_capacity(self.n(), self.m);
        self.for_each_edge(|s, d| el.push(s, d));
        el
    }

    /// Snapshot handoff to the static stack: the transposed, normalized
    /// CSR the synchronous baselines and the DES engine consume.
    pub fn to_csr(&self) -> Result<Csr> {
        Csr::from_edgelist(&self.to_edgelist())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> DeltaGraph {
        // 0->1, 0->2, 1->2, 2->0; 3 dangling
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        DeltaGraph::from_edgelist(&el)
    }

    #[test]
    fn builds_and_dedups() {
        let el = EdgeList::from_edges(3, vec![(0, 1), (0, 1), (1, 2), (0, 0)]).unwrap();
        let g = DeltaGraph::from_edgelist(&el);
        assert_eq!(g.m(), 3);
        assert_eq!(g.out(0), &[0, 1]);
        assert_eq!(g.outdeg(1), 1);
        assert!(g.is_dangling(2));
        assert_eq!(g.dangling_count(), 1);
    }

    #[test]
    fn apply_inserts_removes_and_grows() {
        let mut g = toy();
        let batch = UpdateBatch {
            new_nodes: 2,
            insert: vec![(3, 0), (4, 1), (0, 5), (0, 1)], // (0,1) is a dup
            remove: vec![(1, 2), (2, 3)],                 // (2,3) absent
        };
        let d = g.apply(&batch).unwrap();
        assert_eq!((d.old_n, d.new_n), (4, 6));
        assert_eq!(d.inserted, 3);
        assert_eq!(d.removed, 1);
        assert_eq!(g.n(), 6);
        assert_eq!(g.m(), 4 + 3 - 1);
        assert!(g.has_edge(3, 0) && g.has_edge(4, 1) && g.has_edge(0, 5));
        assert!(!g.has_edge(1, 2));
        assert!(g.is_dangling(1), "1 lost its only out-link");
        assert!(g.is_dangling(5));
        // changed sources carry their OLD lists
        let changed: BTreeMap<_, _> = d.changed_sources.into_iter().collect();
        assert_eq!(changed[&0], vec![1, 2]);
        assert_eq!(changed[&1], vec![2]);
        assert_eq!(changed[&3], Vec::<NodeId>::new());
        assert_eq!(changed[&4], Vec::<NodeId>::new());
        assert!(!changed.contains_key(&2));
        assert_eq!(g.epoch(), 1);
    }

    #[test]
    fn cancelled_mutation_not_reported_changed() {
        let mut g = toy();
        let d = g
            .apply(&UpdateBatch {
                new_nodes: 0,
                insert: vec![(0, 3)],
                remove: vec![(0, 3)],
            })
            .unwrap();
        assert_eq!(d.inserted, 1);
        assert_eq!(d.removed, 1);
        assert!(d.changed_sources.is_empty());
        assert_eq!(g, toy_with_epoch(1));
    }

    fn toy_with_epoch(e: u64) -> DeltaGraph {
        let mut g = toy();
        g.epoch = e;
        g
    }

    #[test]
    fn rejects_out_of_bounds() {
        let mut g = toy();
        let before = g.clone();
        assert!(g
            .apply(&UpdateBatch { new_nodes: 1, insert: vec![(0, 5)], remove: vec![] })
            .is_err());
        assert_eq!(g, before, "failed apply must not mutate");
    }

    #[test]
    fn snapshot_matches_csr_pipeline() {
        let mut g = toy();
        g.apply(&UpdateBatch {
            new_nodes: 1,
            insert: vec![(4, 0), (3, 4)],
            remove: vec![(0, 2)],
        })
        .unwrap();
        let csr = g.to_csr().unwrap();
        csr.validate().unwrap();
        assert_eq!(csr.n(), g.n());
        assert_eq!(csr.nnz(), g.m());
        // outdeg agreement
        for u in 0..g.n() {
            assert_eq!(csr.outdeg()[u] as usize, g.outdeg(u), "node {u}");
        }
        assert_eq!(
            csr.dangling().len(),
            g.dangling_count(),
            "dangling sets must agree"
        );
    }

    #[test]
    fn edgelist_roundtrip() {
        let g = toy();
        let el = g.to_edgelist();
        assert_eq!(DeltaGraph::from_edgelist(&el), g);
    }
}
