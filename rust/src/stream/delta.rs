//! `DeltaGraph` — a mutable, epoch-batched overlay over the static CSR
//! pipeline.
//!
//! The crawl view of the Web is never frozen: pages arrive, links churn.
//! The paper's asynchronous premise (§1) is that synchronized global
//! recomputation is untenable at that scale; this structure supplies the
//! other half of the story — a graph that *changes between solves*.
//!
//! Representation: forward (out-edge) adjacency, sorted and
//! deduplicated per source. That is the orientation a crawler produces
//! and the one the push solver ([`super::PushState`]) walks; the static
//! analysis stack keeps using the transposed [`Csr`] obtained through
//! [`DeltaGraph::to_csr`] (the "snapshot handoff").
//!
//! Updates are applied in batches ([`UpdateBatch`]) — one batch per
//! epoch — and every apply returns an [`AppliedDelta`] recording which
//! sources changed and what their out-lists were, which is exactly the
//! information the warm-start residual injection needs
//! (`PushState::apply_batch`).

use std::collections::BTreeMap;

use crate::graph::{Csr, EdgeList, NodeId};
use crate::Result;

/// One epoch's worth of graph mutations.
///
/// Semantics of `apply`: the node set grows by `new_nodes` first (ids
/// `old_n..old_n + new_nodes`, born dangling), then `insert` edges are
/// added, then `remove` edges are deleted. Inserts of already-present
/// edges and removals of absent edges are no-ops (the adjacency is 0/1,
/// matching CSR dedup semantics).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UpdateBatch {
    pub new_nodes: usize,
    pub insert: Vec<(NodeId, NodeId)>,
    pub remove: Vec<(NodeId, NodeId)>,
}

impl UpdateBatch {
    pub fn is_empty(&self) -> bool {
        self.new_nodes == 0 && self.insert.is_empty() && self.remove.is_empty()
    }

    /// Nominal size of the batch (requested ops, before dedup).
    pub fn len(&self) -> usize {
        self.new_nodes + self.insert.len() + self.remove.len()
    }
}

/// What actually changed when a batch was applied.
#[derive(Debug, Clone)]
pub struct AppliedDelta {
    pub old_n: usize,
    pub new_n: usize,
    /// Effective (post-dedup) edge insertions / removals.
    pub inserted: usize,
    pub removed: usize,
    /// Every source whose out-edge set changed, with its *previous*
    /// out-list (sorted). Sources whose list ended up identical (an
    /// insert cancelled by a removal in the same batch) are omitted.
    pub changed_sources: Vec<(NodeId, Vec<NodeId>)>,
}

/// Cost accounting for one [`DeltaGraph::merge_csr`] splice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MergeCsrStats {
    /// Transposed rows rebuilt entry-by-entry (dirty in-rows of changed
    /// sources, plus rows that arrived since the baseline).
    pub dirty_rows: usize,
    /// Rows copied verbatim from the previous snapshot.
    pub copied_rows: usize,
}

/// Mutable forward-adjacency web graph, updated in epochs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeltaGraph {
    /// Sorted, deduplicated out-neighbors per source.
    out: Vec<Vec<NodeId>>,
    /// Total edge count (Σ out-degrees).
    m: usize,
    /// Number of batches applied so far.
    epoch: u64,
    /// First-touch capture of each changed source's out-list as of the
    /// last CSR baseline (construction or the last [`merge_csr`]) — the
    /// splice set for the incremental snapshot handoff.
    ///
    /// [`merge_csr`]: DeltaGraph::merge_csr
    snapshot_changed: BTreeMap<NodeId, Vec<NodeId>>,
    /// Node / deduped-edge count at the last CSR baseline (guards
    /// `merge_csr` against being handed a mismatched snapshot).
    snapshot_n: usize,
    snapshot_m: usize,
}

impl DeltaGraph {
    /// Empty graph on `n` nodes (all dangling).
    pub fn new(n: usize) -> Self {
        DeltaGraph {
            out: vec![Vec::new(); n],
            m: 0,
            epoch: 0,
            snapshot_changed: BTreeMap::new(),
            snapshot_n: n,
            snapshot_m: 0,
        }
    }

    /// Build from an edge list (duplicates collapsed, like CSR).
    pub fn from_edgelist(el: &EdgeList) -> Self {
        let mut out = vec![Vec::new(); el.n()];
        for &(s, d) in el.edges() {
            out[s as usize].push(d);
        }
        let mut m = 0;
        for l in out.iter_mut() {
            l.sort_unstable();
            l.dedup();
            m += l.len();
        }
        let (n, m0) = (out.len(), m);
        DeltaGraph {
            out,
            m,
            epoch: 0,
            snapshot_changed: BTreeMap::new(),
            snapshot_n: n,
            snapshot_m: m0,
        }
    }

    /// Build the forward adjacency from an already-built (transposed)
    /// CSR snapshot — the giant-graph ingestion path, which streams the
    /// edge file straight into a [`Csr`] and never materializes an edge
    /// list. Walking the transposed rows in ascending destination order
    /// emits each source's out-targets in ascending order, so the
    /// adjacency comes out sorted and deduplicated without a sort pass.
    pub fn from_csr(csr: &Csr) -> Self {
        let n = csr.n();
        let mut out: Vec<Vec<NodeId>> = csr
            .outdeg()
            .iter()
            .map(|&d| Vec::with_capacity(d as usize))
            .collect();
        for i in 0..n {
            let (srcs, _) = csr.row(i);
            for &s in srcs {
                out[s as usize].push(i as NodeId);
            }
        }
        let m = csr.nnz();
        DeltaGraph {
            out,
            m,
            epoch: 0,
            snapshot_changed: BTreeMap::new(),
            snapshot_n: n,
            snapshot_m: m,
        }
    }

    pub fn n(&self) -> usize {
        self.out.len()
    }

    /// Deduplicated edge count.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Batches applied so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    #[inline]
    pub fn outdeg(&self, u: usize) -> usize {
        self.out[u].len()
    }

    /// Sorted out-neighbors of `u`.
    #[inline]
    pub fn out(&self, u: usize) -> &[NodeId] {
        &self.out[u]
    }

    #[inline]
    pub fn is_dangling(&self, u: usize) -> bool {
        self.out[u].is_empty()
    }

    pub fn dangling_count(&self) -> usize {
        self.out.iter().filter(|l| l.is_empty()).count()
    }

    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.out[u as usize].binary_search(&v).is_ok()
    }

    /// Visit every edge (source, target), sources in order.
    pub fn for_each_edge(&self, mut f: impl FnMut(NodeId, NodeId)) {
        for (u, l) in self.out.iter().enumerate() {
            for &v in l {
                f(u as NodeId, v);
            }
        }
    }

    /// Apply one batch; returns the effective delta (see
    /// [`AppliedDelta`]). Fails on out-of-bounds endpoints — the graph
    /// is left untouched in that case.
    pub fn apply(&mut self, batch: &UpdateBatch) -> Result<AppliedDelta> {
        let old_n = self.n();
        let new_n = old_n + batch.new_nodes;
        for &(s, d) in batch.insert.iter().chain(&batch.remove) {
            if s as usize >= new_n || d as usize >= new_n {
                anyhow::bail!(
                    "update edge ({s}, {d}) out of bounds for n={new_n} \
                     (old n {old_n} + {} arrivals)",
                    batch.new_nodes
                );
            }
        }
        self.out.resize(new_n, Vec::new());

        // old out-lists, captured lazily the first time a source changes
        let mut old_lists: BTreeMap<NodeId, Vec<NodeId>> = BTreeMap::new();
        let mut inserted = 0usize;
        let mut removed = 0usize;
        for &(s, d) in &batch.insert {
            let l = &mut self.out[s as usize];
            if let Err(pos) = l.binary_search(&d) {
                old_lists.entry(s).or_insert_with(|| l.clone());
                l.insert(pos, d);
                self.m += 1;
                inserted += 1;
            }
        }
        for &(s, d) in &batch.remove {
            let l = &mut self.out[s as usize];
            if let Ok(pos) = l.binary_search(&d) {
                old_lists.entry(s).or_insert_with(|| l.clone());
                l.remove(pos);
                self.m -= 1;
                removed += 1;
            }
        }

        // drop sources whose list round-tripped back to its old value
        let changed_sources: Vec<(NodeId, Vec<NodeId>)> = old_lists
            .into_iter()
            .filter(|(s, old)| &self.out[*s as usize] != old)
            .collect();

        // accumulate the CSR-baseline capture: the FIRST list a source
        // had after the last materialization wins, so merge_csr sees
        // exactly the delta since its `prev` snapshot even when several
        // batches land between handoffs
        for (s, old) in &changed_sources {
            self.snapshot_changed.entry(*s).or_insert_with(|| old.clone());
        }

        self.epoch += 1;
        Ok(AppliedDelta { old_n, new_n, inserted, removed, changed_sources })
    }

    /// Materialize as an edge list (sorted by source, then target).
    pub fn to_edgelist(&self) -> EdgeList {
        let mut el = EdgeList::with_capacity(self.n(), self.m);
        self.for_each_edge(|s, d| el.push(s, d));
        el
    }

    /// Snapshot handoff to the static stack: the transposed, normalized
    /// CSR the synchronous baselines and the DES engine consume.
    ///
    /// Rebuilds from scratch in O(n + m). For big-graph epoch handoff
    /// prefer [`merge_csr`](DeltaGraph::merge_csr), which splices only
    /// the rows churn actually touched into the previous snapshot.
    /// (This method does not move the merge baseline — interleaving it
    /// with `merge_csr` on the same graph is fine, but keep feeding
    /// `merge_csr` the snapshot chain it produced.)
    pub fn to_csr(&self) -> Result<Csr> {
        // the materialized list is consumed by the build — its buffer
        // IS the sort scratch, so peak memory stays one edge copy
        Csr::from_edgelist_owned(self.to_edgelist())
    }

    /// Incremental snapshot handoff: splice the churn since the last
    /// baseline into `prev` instead of rebuilding the whole matrix.
    ///
    /// `prev` must be the CSR materialized at the current baseline —
    /// construction or the previous `merge_csr` call (guarded by the
    /// recorded `(n, nnz)` of the baseline). Only the transposed rows a
    /// changed source points at (under its old OR new out-list) are
    /// rebuilt entry-by-entry; every other row is copied verbatim, so
    /// the result is row-for-row **bit-identical** to a full
    /// [`to_csr`](DeltaGraph::to_csr) rebuild at
    /// O(dirty rows + copied prefix) splice cost instead of an
    /// O(n + m) sort-and-count. Rows that arrived since the baseline
    /// are rebuilt too (they are either empty or targets of a changed
    /// source).
    pub fn merge_csr(&mut self, prev: &Csr) -> Result<(Csr, MergeCsrStats)> {
        anyhow::ensure!(
            prev.n() == self.snapshot_n && prev.nnz() == self.snapshot_m,
            "merge_csr: prev is n={}/nnz={} but the tracked baseline is n={}/nnz={} — \
             pass the CSR materialized at the last baseline",
            prev.n(),
            prev.nnz(),
            self.snapshot_n,
            self.snapshot_m
        );
        let n = self.n();
        let n0 = prev.n();
        // effective changed sources since the baseline (sources whose
        // list round-tripped back across batches drop out here)
        let changed: Vec<(NodeId, &[NodeId])> = self
            .snapshot_changed
            .iter()
            .filter(|(s, old)| &self.out[**s as usize] != *old)
            .map(|(s, old)| (*s, old.as_slice()))
            .collect();
        // sorted (BTreeMap order) — membership test during the splice
        let changed_ids: Vec<NodeId> = changed.iter().map(|(s, _)| *s).collect();

        // dirty transposed rows: every target a changed source pointed
        // at (entry leaves, or its 1/outdeg weight moved) or points at
        // now (entry arrives, or weight moved)
        let mut dirty = vec![false; n];
        for (s, old) in &changed {
            for &t in *old {
                dirty[t as usize] = true;
            }
            for &t in self.out(*s as usize) {
                dirty[t as usize] = true;
            }
        }

        // replacement entries, sorted by (row, source) so each dirty
        // row's splice is a linear sorted merge
        let mut adds: Vec<(NodeId, NodeId, f32)> = Vec::new();
        for (s, _) in &changed {
            let out = self.out(*s as usize);
            if out.is_empty() {
                continue;
            }
            let w = 1.0 / out.len() as f32;
            for &t in out {
                adds.push((t, *s, w));
            }
        }
        adds.sort_unstable_by_key(|&(t, s, _)| (t, s));

        let mut rowptr = Vec::with_capacity(n + 1);
        let mut cols: Vec<NodeId> = Vec::with_capacity(self.m);
        let mut vals: Vec<f32> = Vec::with_capacity(self.m);
        rowptr.push(0usize);
        let mut ai = 0usize;
        let mut dirty_rows = 0usize;
        for i in 0..n {
            if i >= n0 || dirty[i] {
                dirty_rows += 1;
                let (pc, pv): (&[NodeId], &[f32]) =
                    if i < n0 { prev.row(i) } else { (&[], &[]) };
                let lo = ai;
                while ai < adds.len() && adds[ai].0 as usize == i {
                    ai += 1;
                }
                let row_adds = &adds[lo..ai];
                let mut pi = 0usize;
                let mut qi = 0usize;
                loop {
                    // entries of changed sources are dropped from the
                    // prev side; their new lists re-enter via row_adds
                    while pi < pc.len() && changed_ids.binary_search(&pc[pi]).is_ok() {
                        pi += 1;
                    }
                    match (pi < pc.len(), qi < row_adds.len()) {
                        (false, false) => break,
                        (true, false) => {
                            cols.push(pc[pi]);
                            vals.push(pv[pi]);
                            pi += 1;
                        }
                        (false, true) => {
                            cols.push(row_adds[qi].1);
                            vals.push(row_adds[qi].2);
                            qi += 1;
                        }
                        (true, true) => {
                            // never equal: a surviving prev source is by
                            // definition not a changed one
                            if pc[pi] < row_adds[qi].1 {
                                cols.push(pc[pi]);
                                vals.push(pv[pi]);
                                pi += 1;
                            } else {
                                cols.push(row_adds[qi].1);
                                vals.push(row_adds[qi].2);
                                qi += 1;
                            }
                        }
                    }
                }
            } else {
                // clean row: verbatim copy (adds only target dirty rows,
                // so the cursor cannot be pointing here)
                let (c, v) = prev.row(i);
                cols.extend_from_slice(c);
                vals.extend_from_slice(v);
            }
            rowptr.push(cols.len());
        }
        anyhow::ensure!(
            cols.len() == self.m,
            "merge produced {} nnz but the graph holds {} edges",
            cols.len(),
            self.m
        );

        let outdeg: Vec<u32> = (0..n).map(|u| self.outdeg(u) as u32).collect();
        let dangling: Vec<NodeId> = (0..n as NodeId)
            .filter(|&u| self.out[u as usize].is_empty())
            .collect();
        let csr = Csr::from_raw_parts(n, rowptr, cols, vals, dangling, outdeg);
        self.snapshot_changed.clear();
        self.snapshot_n = n;
        self.snapshot_m = self.m;
        Ok((csr, MergeCsrStats { dirty_rows, copied_rows: n - dirty_rows }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> DeltaGraph {
        // 0->1, 0->2, 1->2, 2->0; 3 dangling
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        DeltaGraph::from_edgelist(&el)
    }

    #[test]
    fn builds_and_dedups() {
        let el = EdgeList::from_edges(3, vec![(0, 1), (0, 1), (1, 2), (0, 0)]).unwrap();
        let g = DeltaGraph::from_edgelist(&el);
        assert_eq!(g.m(), 3);
        assert_eq!(g.out(0), &[0, 1]);
        assert_eq!(g.outdeg(1), 1);
        assert!(g.is_dangling(2));
        assert_eq!(g.dangling_count(), 1);
    }

    #[test]
    fn from_csr_matches_from_edgelist() {
        let el = crate::graph::generators::erdos_renyi(200, 900, 7);
        let via_el = DeltaGraph::from_edgelist(&el);
        let csr = Csr::from_edgelist(&el).unwrap();
        let via_csr = DeltaGraph::from_csr(&csr);
        assert_eq!(via_el, via_csr);
        // and the round trip back through the snapshot handoff agrees
        assert_eq!(via_csr.to_csr().unwrap(), csr);
    }

    #[test]
    fn apply_inserts_removes_and_grows() {
        let mut g = toy();
        let batch = UpdateBatch {
            new_nodes: 2,
            insert: vec![(3, 0), (4, 1), (0, 5), (0, 1)], // (0,1) is a dup
            remove: vec![(1, 2), (2, 3)],                 // (2,3) absent
        };
        let d = g.apply(&batch).unwrap();
        assert_eq!((d.old_n, d.new_n), (4, 6));
        assert_eq!(d.inserted, 3);
        assert_eq!(d.removed, 1);
        assert_eq!(g.n(), 6);
        assert_eq!(g.m(), 4 + 3 - 1);
        assert!(g.has_edge(3, 0) && g.has_edge(4, 1) && g.has_edge(0, 5));
        assert!(!g.has_edge(1, 2));
        assert!(g.is_dangling(1), "1 lost its only out-link");
        assert!(g.is_dangling(5));
        // changed sources carry their OLD lists
        let changed: BTreeMap<_, _> = d.changed_sources.into_iter().collect();
        assert_eq!(changed[&0], vec![1, 2]);
        assert_eq!(changed[&1], vec![2]);
        assert_eq!(changed[&3], Vec::<NodeId>::new());
        assert_eq!(changed[&4], Vec::<NodeId>::new());
        assert!(!changed.contains_key(&2));
        assert_eq!(g.epoch(), 1);
    }

    #[test]
    fn cancelled_mutation_not_reported_changed() {
        let mut g = toy();
        let d = g
            .apply(&UpdateBatch {
                new_nodes: 0,
                insert: vec![(0, 3)],
                remove: vec![(0, 3)],
            })
            .unwrap();
        assert_eq!(d.inserted, 1);
        assert_eq!(d.removed, 1);
        assert!(d.changed_sources.is_empty());
        assert_eq!(g, toy_with_epoch(1));
    }

    fn toy_with_epoch(e: u64) -> DeltaGraph {
        let mut g = toy();
        g.epoch = e;
        g
    }

    #[test]
    fn rejects_out_of_bounds() {
        let mut g = toy();
        let before = g.clone();
        assert!(g
            .apply(&UpdateBatch { new_nodes: 1, insert: vec![(0, 5)], remove: vec![] })
            .is_err());
        assert_eq!(g, before, "failed apply must not mutate");
    }

    #[test]
    fn snapshot_matches_csr_pipeline() {
        let mut g = toy();
        g.apply(&UpdateBatch {
            new_nodes: 1,
            insert: vec![(4, 0), (3, 4)],
            remove: vec![(0, 2)],
        })
        .unwrap();
        let csr = g.to_csr().unwrap();
        csr.validate().unwrap();
        assert_eq!(csr.n(), g.n());
        assert_eq!(csr.nnz(), g.m());
        // outdeg agreement
        for u in 0..g.n() {
            assert_eq!(csr.outdeg()[u] as usize, g.outdeg(u), "node {u}");
        }
        assert_eq!(
            csr.dangling().len(),
            g.dangling_count(),
            "dangling sets must agree"
        );
    }

    #[test]
    fn edgelist_roundtrip() {
        let g = toy();
        let el = g.to_edgelist();
        assert_eq!(DeltaGraph::from_edgelist(&el), g);
    }

    #[test]
    fn merge_csr_matches_full_rebuild_and_counts_dirty_rows() {
        let mut g = toy();
        let prev = g.to_csr().unwrap();
        g.apply(&UpdateBatch {
            new_nodes: 1,
            insert: vec![(4, 0), (3, 4)],
            remove: vec![(0, 2)],
        })
        .unwrap();
        let full = g.to_csr().unwrap();
        let (merged, stats) = g.merge_csr(&prev).unwrap();
        assert_eq!(merged, full, "splice must be bit-identical to the rebuild");
        // dirty rows: 0 and 4 (source 4's new target + source 3's), and
        // 2 (source 0 dropped it + weight change on its survivors)
        assert_eq!(stats.dirty_rows + stats.copied_rows, g.n());
        assert!(stats.dirty_rows < g.n(), "a small batch must not dirty every row");
        assert!(stats.dirty_rows >= 2);
    }

    #[test]
    fn merge_csr_accumulates_batches_between_handoffs() {
        let mut g = toy();
        let prev = g.to_csr().unwrap();
        // three batches between materializations, including a cross-batch
        // round-trip (edge (0,3) inserted then removed)
        g.apply(&UpdateBatch { new_nodes: 0, insert: vec![(0, 3)], remove: vec![] })
            .unwrap();
        g.apply(&UpdateBatch { new_nodes: 0, insert: vec![(3, 1)], remove: vec![(0, 3)] })
            .unwrap();
        g.apply(&UpdateBatch { new_nodes: 2, insert: vec![(5, 3)], remove: vec![(2, 0)] })
            .unwrap();
        let full = g.to_csr().unwrap();
        let (merged, stats) = g.merge_csr(&prev).unwrap();
        assert_eq!(merged, full);
        // and the baseline moved: a second merge chains off the new CSR
        g.apply(&UpdateBatch { new_nodes: 0, insert: vec![(1, 0)], remove: vec![] })
            .unwrap();
        let (merged2, stats2) = g.merge_csr(&merged).unwrap();
        assert_eq!(merged2, g.to_csr().unwrap());
        assert!(stats2.dirty_rows <= stats.dirty_rows + 1);
    }

    #[test]
    fn merge_csr_rejects_mismatched_baseline() {
        let mut g = toy();
        let _baseline = g.to_csr().unwrap();
        g.apply(&UpdateBatch { new_nodes: 1, insert: vec![(4, 0)], remove: vec![] })
            .unwrap();
        // handing it the CURRENT state's CSR (not the baseline) fails
        let wrong = g.to_csr().unwrap();
        assert!(g.merge_csr(&wrong).is_err());
    }

    #[test]
    fn merge_csr_no_churn_is_all_copy() {
        let mut g = toy();
        let prev = g.to_csr().unwrap();
        // a batch that nets out to nothing
        g.apply(&UpdateBatch { new_nodes: 0, insert: vec![(0, 3)], remove: vec![(0, 3)] })
            .unwrap();
        let (merged, stats) = g.merge_csr(&prev).unwrap();
        assert_eq!(merged, prev);
        assert_eq!(stats.dirty_rows, 0);
        assert_eq!(stats.copied_rows, g.n());
    }
}
