//! Vendored, offline subset of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io access, so this shim provides
//! the (small) surface the crate actually uses:
//!
//! * [`Error`] — message + cause chain, `Display`, `{:#}` alternate
//!   formatting that prints the chain, `Debug`;
//! * `From<E>` for any `std::error::Error + Send + Sync + 'static`
//!   (so `?` works on io/parse errors);
//! * [`Result`] alias;
//! * [`Context`] for `Result<_, E: std::error::Error>` and `Option<_>`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Semantics match upstream anyhow closely enough for this crate's
//! usage; swap back to the real crate by deleting this directory and
//! pointing Cargo.toml at the registry.

use std::fmt;

/// Error type: an outermost message plus the chain of causes beneath it
/// (most recent context first).
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, `outer: cause: cause`.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`
// (same as upstream anyhow) — that is what makes this blanket `From`
// coherent alongside std's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context extension for fallible values, mirroring anyhow's trait.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i32> {
        let n: i32 = s
            .parse()
            .with_context(|| format!("parsing {s:?}"))?;
        Ok(n)
    }

    #[test]
    fn from_std_error_and_chain() {
        let e = parse_num("nope").unwrap_err();
        assert!(e.to_string().contains("parsing"));
        let full = format!("{e:#}");
        assert!(full.contains("parsing") && full.contains("invalid digit"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("bad value {x} ({})", "why");
        assert_eq!(e.to_string(), "bad value 3 (why)");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
        fn g(ok: bool) -> Result<()> {
            ensure!(ok, "not ok");
            Ok(())
        }
        assert!(g(true).is_ok());
        assert_eq!(g(false).unwrap_err().to_string(), "not ok");
    }

    #[test]
    fn debug_prints_causes() {
        let e = parse_num("x").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }
}
