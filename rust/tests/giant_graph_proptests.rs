//! Equivalence battery for the giant-graph memory tier (seeded random
//! campaigns, same style as proptests.rs — every failure names its
//! trial/round).
//!
//! Invariants covered:
//!   * the compact (u32 rowptr) CSR tier is *bit-identical* to the wide
//!     (usize) layout everywhere the offsets feed arithmetic: `spmv`
//!     outputs compared bitwise, `merge_csr` splices, and
//!     `balanced_nnz` partitions, across random webs and churn batches;
//!   * the streaming two-pass binary loader builds the same CSR as the
//!     in-memory `from_edgelist` route over random webs, R-MAT streams,
//!     and adversarial chunk sizes;
//!   * sparse per-peer outboxes reach the same fixed point as the dense
//!     accumulators: both policies solve to 1e-9 L1 of each other and
//!     of the power reference with rank mass pinned to 1e-9, at every
//!     shard count in 1..8, with work stealing both off and on, and
//!     across churn epochs with re-balancing (the adopt-partition path
//!     that rebuilds the outboxes).
//!
//! Every test name starts with `giant_`: CI's debug pass skips them
//! (`--skip giant_`) and the release pass runs the whole file.

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::coordinator::Partitioner;
use asyncpr::graph::generators::{
    self, churn_batch, rmat_edges, ChurnParams, RMAT_WEB_PROBS,
};
use asyncpr::graph::io::{save_edgelist_bin_iter, stream_csr_from_bin, StreamCsrOptions};
use asyncpr::graph::{Csr, EdgeList};
use asyncpr::stream::{power_method_f64, DeltaGraph, OutboxPolicy, ShardedPush};
use asyncpr::util::Rng;

fn l1_64(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

fn web(n: usize, seed: u64) -> DeltaGraph {
    let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
    DeltaGraph::from_edgelist(&el)
}

/// The same CSR in both rowptr widths (content equality is
/// width-blind, so the pair is guaranteed to describe one matrix).
fn both_widths(csr: &Csr) -> (Csr, Csr) {
    let mut compact = csr.clone();
    compact.set_compact_rowptr(true);
    let mut wide = csr.clone();
    wide.set_compact_rowptr(false);
    assert!(compact.rowptr_is_compact() && !wide.rowptr_is_compact());
    assert_eq!(compact, wide, "width flip changed the matrix");
    (compact, wide)
}

#[test]
fn giant_compact_vs_wide_spmv_bit_identical() {
    let mut rng = Rng::new(2_001);
    for trial in 0..20u64 {
        let n = rng.range(30, 600);
        let el = generators::power_law_web(&generators::WebParams::scaled(n), 2_100 + trial);
        let csr = Csr::from_edgelist(&el).unwrap();
        let (compact, wide) = both_widths(&csr);
        let x: Vec<f32> = (0..csr.n()).map(|_| rng.f64() as f32).collect();
        let mut yc = vec![0.0f32; csr.n()];
        let mut yw = vec![0.0f32; csr.n()];
        compact.spmv(&x, &mut yc);
        wide.spmv(&x, &mut yw);
        for (i, (a, b)) in yc.iter().zip(&yw).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "trial {trial}: spmv row {i} differs across widths ({a} vs {b})"
            );
        }
        // range form too — the per-UE operators call this one
        let lo = rng.range(0, csr.n());
        let hi = rng.range(lo, csr.n()) + 1;
        let mut yc = vec![0.0f32; hi - lo];
        let mut yw = vec![0.0f32; hi - lo];
        compact.spmv_range(&x, lo, hi, &mut yc);
        wide.spmv_range(&x, lo, hi, &mut yw);
        assert!(
            yc.iter().zip(&yw).all(|(a, b)| a.to_bits() == b.to_bits()),
            "trial {trial}: spmv_range [{lo}, {hi}) differs across widths"
        );
    }
}

#[test]
fn giant_compact_vs_wide_merge_csr_and_balanced_nnz() {
    let mut rng = Rng::new(2_201);
    for trial in 0..10u64 {
        let n = rng.range(50, 400);
        let mut g = web(n, 2_300 + trial);
        let mut prev = g.to_csr().unwrap();
        let churn = ChurnParams::scaled_to(g.n(), g.m());
        for round in 0..8 {
            let batch = churn_batch(&g, &churn, &mut rng);
            // merging consumes the baseline, so run the same batch
            // through two identical overlays — one per prev width
            let mut g2 = g.clone();
            g.apply(&batch).unwrap();
            g2.apply(&batch).unwrap();
            let (prev_compact, prev_wide) = both_widths(&prev);
            let (mc, sc) = g.merge_csr(&prev_compact).unwrap();
            let (mw, sw) = g2.merge_csr(&prev_wide).unwrap();
            assert_eq!(
                mc, mw,
                "trial {trial} round {round}: splice differs across prev widths"
            );
            assert_eq!(
                (sc.dirty_rows, sc.copied_rows),
                (sw.dirty_rows, sw.copied_rows),
                "trial {trial} round {round}: splice stats differ"
            );
            for p in 1..=8usize {
                assert_eq!(
                    Partitioner::balanced_nnz(&mc, p),
                    Partitioner::balanced_nnz(&mw, p),
                    "trial {trial} round {round}: balanced_nnz({p}) differs"
                );
            }
            prev = mc;
        }
    }
}

#[test]
fn giant_streaming_build_matches_in_memory_over_random_webs() {
    let mut rng = Rng::new(2_401);
    let dir = std::env::temp_dir();
    for trial in 0..12u64 {
        let el = if trial % 3 == 0 {
            // R-MAT stream (the giant bench's generator), duplicates and
            // self-loops included
            let scale = 6 + (trial % 4) as u32;
            let mut el = EdgeList::new(1usize << scale);
            for (s, d) in rmat_edges(scale, (1usize << scale) * 6, RMAT_WEB_PROBS, 2_500 + trial) {
                el.push(s, d);
            }
            el
        } else {
            let n = rng.range(20, 500);
            generators::power_law_web(&generators::WebParams::scaled(n), 2_600 + trial)
        };
        let want = Csr::from_edgelist(&el).unwrap();
        let path = dir.join(format!("asyncpr_giant_prop_{trial}.bin"));
        save_edgelist_bin_iter(&path, el.n(), el.edges().len() as u64, el.edges().iter().copied())
            .unwrap();
        // adversarial chunk sizes: record-straddling reads must not move
        // a single column
        for chunk_bytes in [7usize, 64, 1 << 20] {
            let opts = StreamCsrOptions { chunk_bytes, ..Default::default() };
            let got = stream_csr_from_bin(&path, &opts).unwrap();
            assert_eq!(got, want, "trial {trial} chunk {chunk_bytes}: streamed CSR differs");
            assert!(got.rowptr_is_compact(), "trial {trial}: small nnz must narrow");
        }
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn giant_sparse_outbox_matches_dense_and_power_all_shard_counts() {
    for shards in 1..=8usize {
        let g = web(600, 2_700 + shards as u64);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let mut ranks = Vec::new();
        for policy in [OutboxPolicy::Dense, OutboxPolicy::Sparse] {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            sp.set_outbox_policy(policy);
            assert_eq!(sp.outbox_policy(), policy);
            let st = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "shards {shards} {policy:?}: never converged");
            let mass = sp.mass();
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "shards {shards} {policy:?}: mass {mass}"
            );
            let d = l1_64(&sp.ranks(), &xref);
            assert!(d < 1e-9, "shards {shards} {policy:?}: L1 vs power {d}");
            ranks.push(sp.ranks());
        }
        let d = l1_64(&ranks[0], &ranks[1]);
        assert!(d < 1e-9, "shards {shards}: dense vs sparse outbox drift {d}");
    }
}

#[test]
fn giant_sparse_outbox_steal_interleaved_matches_power() {
    // shards 1..8 with scripted steals between budgeted solve chunks:
    // ownership moves while sparse outboxes hold undelivered mass, and
    // nothing is allowed to notice
    let mut rng = Rng::new(2_801);
    for shards in 1..=8usize {
        let g = web(500, 2_900 + shards as u64);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        sp.set_outbox_policy(OutboxPolicy::Sparse);
        sp.round_pushes = 512;
        for round in 0..60 {
            let st = sp.solve(&g, 1e-11, 1_500);
            if st.converged {
                break;
            }
            if shards >= 2 {
                for _ in 0..3 {
                    let victim = rng.range(0, shards);
                    let mut thief = rng.range(0, shards);
                    if thief == victim {
                        thief = (thief + 1) % shards;
                    }
                    sp.steal_rows(victim, thief, 1 + rng.range(0, 24));
                }
            }
            let mass = sp.mass();
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "shards {shards} round {round}: mass {mass} mid-steal"
            );
        }
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged, "shards {shards}: never converged");
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-9, "shards {shards}: sparse-outbox steal drift {d}");
    }
}

#[test]
fn giant_sparse_outbox_threaded_steal_matches_power() {
    let tol = 1e-10;
    let g = web(2_000, 3_001);
    let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 100_000);
    for steal in [false, true] {
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        sp.set_outbox_policy(OutboxPolicy::Sparse);
        let opts = PushThreadOptions { tol, steal, steal_batch: 32, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        if !tm.converged {
            assert!(sp.solve(&g, tol, u64::MAX).converged, "steal {steal}: polish");
        }
        let mass = sp.mass();
        assert!((mass - 1.0).abs() < 1e-9, "steal {steal}: mass {mass}");
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-8, "steal {steal}: threaded sparse-outbox drift {d}");
    }
}

#[test]
fn giant_sparse_outbox_churn_epochs_with_rebalance() {
    // churn + rebalance exercises adopt_partition, which rebuilds the
    // outbox vector under the active policy
    let mut g = web(800, 3_101);
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(3_102);
    let mut sp = ShardedPush::new(&g, 0.85, 6);
    sp.set_outbox_policy(OutboxPolicy::Sparse);
    assert!(sp.solve(&g, 1e-11, u64::MAX).converged);
    for epoch in 0..6 {
        let batch = churn_batch(&g, &churn, &mut rng);
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        sp.rebalance(&g, 1.3);
        assert!(sp.solve(&g, 1e-11, u64::MAX).converged, "epoch {epoch}");
        let mass = sp.mass();
        assert!((mass - 1.0).abs() < 1e-9, "epoch {epoch}: mass {mass}");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-9, "epoch {epoch}: drift {d}");
    }
}

#[test]
fn giant_auto_policy_goes_sparse_above_the_threshold() {
    use asyncpr::stream::SPARSE_OUTBOX_SHARDS;
    let g = web(SPARSE_OUTBOX_SHARDS * 40, 3_201);
    let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
    // one below, at, and above the Auto cut-over: the representation
    // flips but the fixed point must not
    for shards in [SPARSE_OUTBOX_SHARDS - 1, SPARSE_OUTBOX_SHARDS, SPARSE_OUTBOX_SHARDS + 1] {
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        assert_eq!(sp.outbox_policy(), OutboxPolicy::Auto);
        assert!(sp.solve(&g, 1e-11, u64::MAX).converged, "shards {shards}");
        let mass = sp.mass();
        assert!((mass - 1.0).abs() < 1e-9, "shards {shards}: mass {mass}");
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-9, "shards {shards}: auto-policy drift {d}");
    }
}
