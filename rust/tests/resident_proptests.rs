//! Cross-epoch invariant suite for the epoch-resident sharded push
//! path (seeded random campaigns, same style as proptests.rs — every
//! failure names its trial/round).
//!
//! Invariants covered:
//!   * `DeltaGraph::merge_csr(prev)` is row-for-row identical to the
//!     full `to_csr()` rebuild across 100+ random churn batches
//!     (insertions, deletions, dangling transitions, node arrivals);
//!   * the resident `ShardedPush::apply_batch` path converges to the
//!     same ranks as the scatter -> inject -> re-scatter path to 1e-9
//!     L1, with `Σp + R/(1-α) = 1` holding to 1e-9 after every epoch,
//!     for 10 epochs with re-balancing enabled at every shard count in
//!     1..8;
//!   * the threaded resident path (real workers + entry re-balancing)
//!     stays on the power-method reference across churn epochs;
//!   * the `repro stream --resident` driver meets the acceptance shape
//!     end-to-end and is deterministic at `threads = 1`.
//!
//! Every test name starts with `resident_`: CI's debug pass skips them
//! (`--skip resident_`) and the release pass runs the whole file.

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::coordinator::experiments::{self, StreamOptions};
use asyncpr::graph::generators::{self, churn_batch, ChurnParams};
use asyncpr::stream::{power_method_f64, DeltaGraph, PushState, ShardedPush, UpdateBatch};
use asyncpr::util::Rng;

fn l1_64(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

fn web(n: usize, seed: u64) -> DeltaGraph {
    let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
    DeltaGraph::from_edgelist(&el)
}

/// Random batch exercising every churn mode: inserts (existing and
/// arriving endpoints), deletions, a forced all-out-links deletion
/// (node becomes dangling), and a forced un-dangling edge.
fn random_batch(rng: &mut Rng, g: &DeltaGraph) -> UpdateBatch {
    let n0 = g.n();
    let new_nodes = rng.range(0, 4);
    let n1 = n0 + new_nodes;
    let mut b = UpdateBatch { new_nodes, ..Default::default() };
    for _ in 0..rng.range(0, 25) {
        b.insert
            .push((rng.range(0, n1) as u32, rng.range(0, n1) as u32));
    }
    let mut edges = Vec::new();
    g.for_each_edge(|s, d| edges.push((s, d)));
    if !edges.is_empty() {
        for _ in 0..rng.range(0, 15) {
            b.remove.push(edges[rng.range(0, edges.len())]);
        }
        // dangling transition: strip one source bare
        let (s, _) = edges[rng.range(0, edges.len())];
        for &(es, ed) in &edges {
            if es == s {
                b.remove.push((es, ed));
            }
        }
    }
    // and give one dangling page an out-link (uniform column -> sparse)
    if let Some(u) = (0..n0).find(|&u| g.is_dangling(u)) {
        b.insert.push((u as u32, rng.range(0, n0) as u32));
    }
    b
}

#[test]
fn resident_merge_csr_matches_full_rebuild_100plus_batches() {
    let mut rng = Rng::new(901);
    let mut batches = 0usize;
    for trial in 0..10u64 {
        let n = rng.range(50, 400);
        let mut g = web(n, 9_000 + trial);
        let mut csr = g.to_csr().unwrap();
        for round in 0..12 {
            let batch = random_batch(&mut rng, &g);
            g.apply(&batch).unwrap();
            let full = g.to_csr().unwrap();
            let (merged, stats) = g.merge_csr(&csr).unwrap();
            assert_eq!(
                merged, full,
                "trial {trial} round {round}: splice != rebuild"
            );
            assert_eq!(
                stats.dirty_rows + stats.copied_rows,
                g.n(),
                "trial {trial} round {round}: row accounting"
            );
            csr = merged;
            batches += 1;
        }
    }
    assert!(batches >= 100, "campaign too small: {batches} batches");
}

#[test]
fn resident_matches_roundtrip_10_epochs_all_shard_counts() {
    for shards in 1..=8usize {
        let mut g = web(800, 70 + shards as u64);
        let churn = ChurnParams::scaled_to(g.n(), g.m());
        let mut rng = Rng::new(500 + shards as u64);

        let mut resident = ShardedPush::new(&g, 0.85, shards);
        let st = resident.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged, "shards {shards}: cold build");
        let mut state = PushState::new(g.n(), 0.85);
        state.begin_epoch();
        state.solve(&g, 1e-11, u64::MAX);

        for epoch in 0..10 {
            let batch = churn_batch(&g, &churn, &mut rng);
            let delta = g.apply(&batch).unwrap();

            // resident: inject into the live shards, re-balance, drain
            resident.begin_epoch();
            resident.apply_batch(&g, &delta);
            resident.rebalance(&g, 1.5);
            let st = resident.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "shards {shards} epoch {epoch}: resident");
            let mass = resident.mass();
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "shards {shards} epoch {epoch}: mass {mass}"
            );

            // roundtrip: global inject, scatter, drain, gather
            state.begin_epoch();
            state.apply_batch(&g, &delta);
            let mut sp = ShardedPush::from_state(&state, &g, shards);
            let st2 = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st2.converged, "shards {shards} epoch {epoch}: roundtrip");
            sp.gather_into(&mut state);

            let d = l1_64(&resident.ranks(), state.ranks());
            assert!(
                d < 1e-9,
                "shards {shards} epoch {epoch}: resident vs roundtrip drift {d}"
            );
        }
    }
}

#[test]
fn resident_threaded_path_tracks_power_reference() {
    let tol = 1e-10;
    let mut g = web(2_000, 81);
    let mut sharded = ShardedPush::new(&g, 0.85, 4);
    let opts = PushThreadOptions {
        tol,
        rebalance_factor: Some(1.5),
        ..Default::default()
    };
    let tm = run_threaded_push(&g, &mut sharded, &opts);
    if !tm.converged {
        let st = sharded.solve(&g, tol, u64::MAX);
        assert!(st.converged, "cold polish");
    }
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(82);
    for epoch in 0..5 {
        let batch = churn_batch(&g, &churn, &mut rng);
        let delta = g.apply(&batch).unwrap();
        sharded.begin_epoch();
        sharded.apply_batch(&g, &delta);
        let mass = sharded.mass();
        assert!((mass - 1.0).abs() < 1e-9, "epoch {epoch}: inject mass {mass}");
        let tm = run_threaded_push(&g, &mut sharded, &opts);
        if !tm.converged {
            let st = sharded.solve(&g, tol, u64::MAX);
            assert!(st.converged, "epoch {epoch}: polish");
        }
        let mass = sharded.mass();
        assert!((mass - 1.0).abs() < 1e-9, "epoch {epoch}: post mass {mass}");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-11, 100_000);
        let d = l1_64(&sharded.ranks(), &xref);
        assert!(d < 1e-8, "epoch {epoch}: L1 vs power {d}");
    }
}

#[test]
fn resident_stream_driver_meets_acceptance_shape() {
    let opts = StreamOptions {
        epochs: 3,
        seed: 9,
        threads: 4,
        resident: true,
        rebalance_factor: Some(1.5),
        ..Default::default()
    };
    let rep = experiments::stream_epochs("scaled:3000", &opts).unwrap();
    assert_eq!(rep.rows.len(), 4);
    assert_eq!(rep.rows[0].csr_dirty_rows, 0, "epoch 0 has no splice");
    for r in &rep.rows {
        assert!(r.l1_vs_power < 1e-8, "epoch {}: L1 {}", r.epoch, r.l1_vs_power);
    }
    for r in &rep.rows[1..] {
        assert!(r.inserted + r.new_nodes > 0, "churn must do something");
        assert!(
            r.csr_dirty_rows > 0 && r.csr_dirty_rows < r.n,
            "epoch {}: splice rebuilt {} of {} rows",
            r.epoch,
            r.csr_dirty_rows,
            r.n
        );
    }
    assert!(rep.final_l1_vs_power < 1e-8);
    // resident warm epochs stay far cheaper than from-scratch even with
    // staleness-inflated parallel pushes (aggregate: per-epoch counts
    // wobble with the schedule)
    assert!(
        rep.update_scratch_pushes as f64 / rep.update_inc_pushes.max(1) as f64 > 2.0,
        "resident warm start saved too little: {} vs {}",
        rep.update_inc_pushes,
        rep.update_scratch_pushes
    );
}

#[test]
fn resident_stream_driver_deterministic_single_thread() {
    let opts = StreamOptions {
        epochs: 2,
        seed: 11,
        threads: 1,
        resident: true,
        rebalance_factor: Some(1.5),
        ..Default::default()
    };
    let a = experiments::stream_epochs("scaled:1500", &opts).unwrap();
    let b = experiments::stream_epochs("scaled:1500", &opts).unwrap();
    assert_eq!(a.rows.len(), b.rows.len());
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.inc_pushes, rb.inc_pushes);
        assert_eq!(ra.inc_touched, rb.inc_touched);
        assert_eq!(ra.scratch_pushes, rb.scratch_pushes);
        assert_eq!(ra.csr_dirty_rows, rb.csr_dirty_rows);
        assert_eq!(ra.m, rb.m);
        assert_eq!(ra.l1_vs_power, rb.l1_vs_power);
    }
}

// ---------------------------------------------------------------------
// Intra-epoch work stealing (PR 5): ownership may move mid-solve and
// nothing is allowed to notice — mass conserves to 1e-9 after every
// steal, the steal-interleaved sharded solve equals power to 1e-9 L1
// at every shard count in 1..8, and rebalance folds the OwnerMap back
// to contiguous bounds afterwards.
// ---------------------------------------------------------------------

#[test]
fn resident_steal_interleaved_solve_matches_power_at_shards_1_to_8() {
    let mut rng = Rng::new(1201);
    for shards in 1..=8usize {
        let g = web(700, 1_100 + shards as u64);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        sp.round_pushes = 512;
        // interleave budgeted solve chunks with scripted random steals:
        // arbitrary interleavings of who pushes what must not move the
        // fixed point (the D-Iteration license)
        for round in 0..60 {
            let st = sp.solve(&g, 1e-11, 1_500);
            if st.converged {
                break;
            }
            if shards >= 2 {
                for _ in 0..3 {
                    let victim = rng.range(0, shards);
                    let mut thief = rng.range(0, shards);
                    if thief == victim {
                        thief = (thief + 1) % shards;
                    }
                    sp.steal_rows(victim, thief, 1 + rng.range(0, 24));
                }
            }
            let mass = sp.mass();
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "shards {shards} round {round}: mass {mass} mid-steal"
            );
        }
        let st = sp.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged, "shards {shards}: never converged");
        assert!((sp.mass() - 1.0).abs() < 1e-9, "shards {shards}: final mass");
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-9, "shards {shards}: steal-interleaved drift {d}");
        if shards >= 2 {
            assert!(sp.steal_totals().0 > 0, "shards {shards}: script never stole");
            // the epoch boundary folds ownership back to plain bounds
            sp.repatriate();
            assert!(sp.owner_map().is_contiguous());
            let d = l1_64(&sp.ranks(), &xref);
            assert!(d < 1e-9, "shards {shards}: repatriation moved ranks ({d})");
        }
    }
}

#[test]
fn resident_steal_epochs_with_rebalance_match_power() {
    // churn epochs with BOTH balance mechanisms active: scripted steals
    // inside the epoch, the bounds re-balancer between epochs (which
    // must fold the stolen ownership back before re-cutting)
    let mut g = web(900, 1_301);
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(1_302);
    let shards = 5usize;
    let mut sp = ShardedPush::new(&g, 0.85, shards);
    assert!(sp.solve(&g, 1e-11, u64::MAX).converged);
    for epoch in 0..6 {
        let batch = churn_batch(&g, &churn, &mut rng);
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        // steal mid-epoch...
        sp.round_pushes = 256;
        let st = sp.solve(&g, 1e-11, 800);
        if !st.converged {
            let victim = rng.range(0, shards);
            let thief = (victim + 1 + rng.range(0, shards - 1)) % shards;
            sp.steal_rows(victim, thief, 16);
        }
        sp.round_pushes = 4096;
        assert!(sp.solve(&g, 1e-11, u64::MAX).converged, "epoch {epoch}");
        let mass = sp.mass();
        assert!((mass - 1.0).abs() < 1e-9, "epoch {epoch}: mass {mass}");
        // ...then rebalance at the boundary: always leaves contiguous
        // ownership, whether or not the bounds moved
        sp.rebalance(&g, 1.3);
        assert!(sp.owner_map().is_contiguous(), "epoch {epoch}: rebalance left overlay");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-9, "epoch {epoch}: drift {d}");
    }
}

#[test]
fn resident_steal_threaded_hot_spot_stays_exact() {
    // the workload stealing exists for: a churn burst confined to one
    // shard's rows, drained on real threads with stealing enabled —
    // whatever the scheduler does, the state must stay exact
    let tol = 1e-10;
    let mut g = web(3_000, 1_401);
    let mut sp = ShardedPush::new(&g, 0.85, 4);
    assert!(sp.solve(&g, tol, u64::MAX).converged);
    let bounds = sp.partitioner().bounds().to_vec();
    let (blo, bhi) = (bounds[bounds.len() - 2], bounds[bounds.len() - 1]);
    let mut rng = Rng::new(1_402);
    for epoch in 0..3 {
        let mut batch = UpdateBatch::default();
        for _ in 0..400 {
            batch
                .insert
                .push((rng.range(blo, bhi) as u32, rng.range(blo, bhi) as u32));
        }
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        let topts = PushThreadOptions { tol, steal: true, steal_batch: 32, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &topts);
        if !tm.converged {
            assert!(sp.solve(&g, tol, u64::MAX).converged, "epoch {epoch}");
        }
        let mass = sp.mass();
        assert!((mass - 1.0).abs() < 1e-9, "epoch {epoch}: mass {mass}");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let d = l1_64(&sp.ranks(), &xref);
        assert!(d < 1e-8, "epoch {epoch}: threaded steal drift {d}");
    }
}

#[test]
fn resident_steal_stream_driver_meets_acceptance_shape() {
    let opts = StreamOptions {
        epochs: 3,
        seed: 13,
        threads: 4,
        resident: true,
        rebalance_factor: Some(1.5),
        steal: true,
        steal_batch: 32,
        ..Default::default()
    };
    let rep = experiments::stream_epochs("scaled:3000", &opts).unwrap();
    assert_eq!(rep.rows.len(), 4);
    for r in &rep.rows {
        assert!(r.l1_vs_power < 1e-8, "epoch {}: L1 {}", r.epoch, r.l1_vs_power);
    }
    // stealing is opportunistic — the driver must ACCEPT both a quiet
    // run (no idle window opened) and an active one; the columns just
    // have to be consistent
    for r in &rep.rows {
        assert!(
            (r.stolen_rows == 0) == (r.steal_grants == 0),
            "epoch {}: {} rows across {} grants",
            r.epoch,
            r.stolen_rows,
            r.steal_grants
        );
    }
}

#[test]
fn resident_steal_requires_at_least_two_threads() {
    let opts = StreamOptions { steal: true, threads: 1, ..Default::default() };
    let err = experiments::stream_epochs("scaled:500", &opts).unwrap_err();
    assert!(err.to_string().contains("--steal"), "unhelpful error: {err}");
}
