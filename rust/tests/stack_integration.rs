//! Whole-stack integration: the AOT artifacts (L1 Pallas kernel inside
//! the L2 jax model, lowered to HLO text) executed from the L3
//! coordinator via PJRT, cross-validated against the native path.
//!
//! Requires `make artifacts` and a build with `--features xla` (the
//! default offline build substitutes a stub PJRT engine that cannot
//! execute kernels, so this whole suite is feature-gated).
#![cfg(feature = "xla")]

use std::sync::Arc;

use asyncpr::asynciter::{ArtifactBlockOp, BlockOperator, Mode, NativeBlockOp, RunSpec, SimEngine};
use asyncpr::config::RunConfig;
use asyncpr::coordinator::{self, Partitioner};
use asyncpr::graph::{generators, Csr};
use asyncpr::pagerank::{l1_diff, normalize_l1, PagerankProblem};
use asyncpr::runtime::Engine;
use asyncpr::simnet::ClusterProfile;

fn engine() -> Engine {
    Engine::new(asyncpr::runtime::default_artifacts_dir())
        .expect("run `make artifacts` before cargo test")
}

fn problem(n: usize, seed: u64) -> Arc<PagerankProblem> {
    let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
    Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
}

#[test]
fn full_async_run_on_artifacts_matches_native() {
    let eng = engine();
    let problem = problem(900, 31);
    let p = 3;
    let profile = ClusterProfile::test_profile(p);
    let spec = RunSpec::paper_table1(Mode::Asynchronous);

    let run_native = || {
        let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(NativeBlockOp::new(problem.clone(), lo, hi))
                    as Box<dyn BlockOperator>
            })
            .collect();
        SimEngine::new(&profile, &problem).run(&mut ops, &spec)
    };
    let run_artifact = || {
        let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(ArtifactBlockOp::new(&eng, problem.clone(), lo, hi, 8).unwrap())
                    as Box<dyn BlockOperator>
            })
            .collect();
        SimEngine::new(&profile, &problem).run(&mut ops, &spec)
    };

    let native = run_native();
    let art = run_artifact();
    // same DES schedule (same seeds, same block nnz) => same iteration
    // counts; numerics agree to f32 kernel tolerance
    assert_eq!(native.iters, art.iters, "DES schedule must be identical");
    let mut a = native.x.clone();
    let mut b = art.x.clone();
    normalize_l1(&mut a);
    normalize_l1(&mut b);
    let d = l1_diff(&a, &b);
    assert!(d < 1e-4, "native vs artifact drift {d}");
}

#[test]
fn sync_run_on_artifacts_converges() {
    let eng = engine();
    let problem = problem(700, 32);
    let p = 2;
    let profile = ClusterProfile::test_profile(p);
    let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
        .blocks()
        .into_iter()
        .map(|(lo, hi)| {
            Box::new(ArtifactBlockOp::new(&eng, problem.clone(), lo, hi, 8).unwrap())
                as Box<dyn BlockOperator>
        })
        .collect();
    let m = SimEngine::new(&profile, &problem)
        .run(&mut ops, &RunSpec::paper_table1(Mode::Synchronous));
    assert!(m.final_global_residual < 2e-6, "resid {}", m.final_global_residual);
}

#[test]
fn run_experiment_with_artifact_config() {
    let eng = engine();
    let cfg = RunConfig {
        graph: "scaled:800".into(),
        procs: 2,
        use_artifact: true,
        ell_width: 8,
        ..Default::default()
    };
    let m = coordinator::run_experiment(&cfg, Some(&eng)).unwrap();
    assert!(m.iters.iter().all(|&i| i > 5));
    assert!(m.final_global_residual < 1e-3);
}

#[test]
fn artifact_op_reports_bucket() {
    let eng = engine();
    let problem = problem(500, 33);
    let op = ArtifactBlockOp::new(&eng, problem, 0, 500, 8).unwrap();
    // n=500 fits the tiny bucket (n=1024) as long as virtual rows fit
    assert!(!op.bucket_name().is_empty());
}

#[test]
fn artifact_rejects_oversized_problem() {
    let eng = engine();
    // 2^21 rows exceeds every bucket
    let err = eng.pagerank_step(1 << 21, 1 << 20, 16);
    let msg = match err {
        Ok(_) => panic!("oversized problem must not fit any bucket"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("no artifact bucket"), "{msg}");
}
