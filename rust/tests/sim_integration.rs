//! Integration tests: the full simulation stack through the public API
//! (graph → problem → partition → operators → DES engine → metrics).

use std::sync::Arc;

use asyncpr::asynciter::{Mode, NativeBlockOp, RunSpec, SimEngine, StopRule};
use asyncpr::config::RunConfig;
use asyncpr::coordinator::{self, experiments, Partitioner};
use asyncpr::graph::{generators, Csr};
use asyncpr::pagerank::{l1_diff, normalize_l1, power_method, PagerankProblem, PowerOptions};
use asyncpr::simnet::ClusterProfile;

fn small_problem(seed: u64) -> Arc<PagerankProblem> {
    let el = generators::power_law_web(&generators::WebParams::scaled(2_000), seed);
    Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
}

fn ops_for(
    problem: &Arc<PagerankProblem>,
    p: usize,
) -> Vec<Box<dyn asyncpr::asynciter::BlockOperator>> {
    Partitioner::consecutive(problem.n(), p)
        .blocks()
        .into_iter()
        .map(|(lo, hi)| {
            Box::new(NativeBlockOp::new(problem.clone(), lo, hi))
                as Box<dyn asyncpr::asynciter::BlockOperator>
        })
        .collect()
}

#[test]
fn sync_run_matches_power_method() {
    let problem = small_problem(1);
    let profile = ClusterProfile::test_profile(3);
    let mut ops = ops_for(&problem, 3);
    let spec = RunSpec::paper_table1(Mode::Synchronous);
    let m = SimEngine::new(&profile, &problem).run(&mut ops, &spec);

    // all UEs run the same number of rounds
    assert!(m.iters.iter().all(|&i| i == m.iters[0]), "{:?}", m.iters);
    // same iterate as the single-UE power method (same tol)
    let pm = power_method(&problem, &PowerOptions::default());
    assert_eq!(m.iters[0], pm.iters as u64, "sync rounds == power iters");
    let mut a = m.x.clone();
    let mut b = pm.x.clone();
    normalize_l1(&mut a);
    normalize_l1(&mut b);
    assert!(l1_diff(&a, &b) < 1e-5);
    // sync imports are complete: every peer fragment of every round
    for i in 0..3 {
        for j in 0..3 {
            if i != j {
                // receiver imported (iters-1)..iters fragments from each peer
                let got = m.imports[i][j];
                let want = m.iters[j];
                assert!(
                    got >= want - 1 && got <= want,
                    "imports[{i}][{j}]={got} want ~{want}"
                );
            }
        }
    }
    assert!(m.import_pct.iter().all(|&p| p > 95.0), "{:?}", m.import_pct);
}

#[test]
fn async_run_converges_with_protocol() {
    let problem = small_problem(2);
    let profile = ClusterProfile::test_profile(4);
    let mut ops = ops_for(&problem, 4);
    let spec = RunSpec::paper_table1(Mode::Asynchronous);
    let m = SimEngine::new(&profile, &problem).run(&mut ops, &spec);

    // stopped via Figure-1, reached a sane global residual
    assert!(m.final_global_residual < 1e-3, "resid {}", m.final_global_residual);
    assert!(m.iters.iter().all(|&i| i > 0));
    // ranking matches the reference
    let pm = power_method(&problem, &PowerOptions { tol: 1e-9, max_iters: 10_000, record_residuals: false });
    let tau = asyncpr::pagerank::kendall_tau(&m.x, &pm.x);
    assert!(tau > 0.999, "tau {tau}");
}

#[test]
fn async_needs_more_iters_than_sync_on_congested_net() {
    // the paper's central observation: staleness costs iterations
    let problem = small_problem(3);
    // congested profile: fragments take ~as long as compute
    let n = problem.n();
    let mut profile = ClusterProfile::test_profile(4);
    profile.bandwidth = (n as f64 / 4.0) * 8.0 / 2e-3; // ~2 ms per fragment
    let mut ops_sync = ops_for(&problem, 4);
    let mut ops_async = ops_for(&problem, 4);
    let eng = SimEngine::new(&profile, &problem);
    let sync = eng.run(&mut ops_sync, &RunSpec::paper_table1(Mode::Synchronous));
    let asyn = eng.run(&mut ops_async, &RunSpec::paper_table1(Mode::Asynchronous));
    let (_, amax) = asyn.iters_range();
    assert!(
        amax >= sync.iters[0],
        "async max iters {amax} should be >= sync {}",
        sync.iters[0]
    );
}

#[test]
fn deterministic_given_seed() {
    let problem = small_problem(4);
    let profile = ClusterProfile::test_profile(3);
    let spec = RunSpec::paper_table1(Mode::Asynchronous);
    let run = || {
        let mut ops = ops_for(&problem, 3);
        SimEngine::new(&profile, &problem).run(&mut ops, &spec)
    };
    let a = run();
    let b = run();
    assert_eq!(a.iters, b.iters);
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.imports, b.imports);
    assert_eq!(a.x, b.x);
}

#[test]
fn different_seeds_differ() {
    let problem = small_problem(4);
    let profile = ClusterProfile::test_profile(3);
    let mut spec = RunSpec::paper_table1(Mode::Asynchronous);
    let mut ops1 = ops_for(&problem, 3);
    let a = SimEngine::new(&profile, &problem).run(&mut ops1, &spec);
    spec.seed = 43;
    let mut ops2 = ops_for(&problem, 3);
    let b = SimEngine::new(&profile, &problem).run(&mut ops2, &spec);
    assert_ne!(a.total_time, b.total_time);
}

#[test]
fn global_threshold_stop_rule() {
    let problem = small_problem(5);
    let profile = ClusterProfile::test_profile(2);
    let mut ops = ops_for(&problem, 2);
    let spec = RunSpec {
        mode: Mode::Asynchronous,
        stop: StopRule::GlobalThreshold { tol: 1e-5 },
        adaptive: false,
        seed: 1,
        max_total_iters: 100_000,
    };
    let m = SimEngine::new(&profile, &problem).run(&mut ops, &spec);
    assert!(m.final_global_residual < 1e-5);
}

#[test]
fn run_experiment_via_config() {
    let cfg = RunConfig {
        graph: "scaled:1500".into(),
        procs: 2,
        mode: Mode::Synchronous,
        ..Default::default()
    };
    let m = coordinator::run_experiment(&cfg, None).unwrap();
    assert!(m.iters[0] > 10);
    // erdos + file paths also load
    let cfg2 = RunConfig { graph: "erdos:500:2500".into(), procs: 2, ..Default::default() };
    let m2 = coordinator::run_experiment(&cfg2, None).unwrap();
    assert!(m2.iters.iter().all(|&i| i > 0));
}

#[test]
fn experiment_ctx_table1_speedup_positive() {
    // mini-Table-1 on the paper's (scaled) operating point: the async
    // run must beat sync when the network dominates (2 UEs keep it fast)
    let base = RunConfig {
        graph: "scaled:3000".into(),
        // keep the paper's wire-saturation ratio at this small scale
        bandwidth_scale: asyncpr::simnet::ClusterProfile::demand_matched_scale(3_000, 2),
        ..Default::default()
    };
    let ctx = experiments::ExperimentCtx::new(base).unwrap();
    let rows = experiments::table1(&ctx, &[2]).unwrap();
    let (row, sync, asyn) = &rows[0];
    assert_eq!(row.procs, 2);
    assert!(row.sync_iters > 10);
    // staleness costs iterations at full scale; at toy scale (few
    // imports total) the local stop can fire within a few rounds of the
    // sync count — require the async count to be at least commensurate
    assert!(
        row.async_iters_max as f64 >= row.sync_iters as f64 * 0.8,
        "async iteration count must be commensurate: async {} vs sync {}",
        row.async_iters_max,
        row.sync_iters
    );
    assert!(sync.total_time > 0.0 && asyn.total_time > 0.0);
    assert!(row.speedup > 1.0, "paper regime: async wins (got {})", row.speedup);
}
