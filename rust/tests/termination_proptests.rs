//! Property suite for the §4.2 termination protocol (seeded random
//! campaigns, same style as proptests.rs — the offline build carries
//! no proptest crate, so generators are explicit).
//!
//! Invariants covered:
//!   * channel-driven ports under random streak schedules: a worker
//!     that announced, diverged, and re-converged re-announces, and
//!     the monitor's reset log means sustained global convergence
//!     ALWAYS reaches STOP from any message history (liveness);
//!   * the acceptance criterion of the termination issue: across
//!     shard counts 1..8, with and without work stealing, and with a
//!     worker stalled mid-solve, a [`StopCause::Protocol`] stop is a
//!     sound stop — the gather-time exact residual is under tol and
//!     rank mass is conserved.

use asyncpr::asynciter::{
    run_threaded_push, PushThreadOptions, StallInjection, StopCause, TermMode,
};
use asyncpr::stream::{DeltaGraph, ShardedPush, UpdateBatch};
use asyncpr::termination::{term_channel, MonitorPort, TermPort};
use asyncpr::util::Rng;

#[test]
fn termination_random_streaks_reannounce_and_always_reach_stop() {
    let mut rng = Rng::new(4207);
    for trial in 0..150 {
        let p = rng.range(1, 6);
        let pc_max = rng.range(1, 4) as u32;
        let (tx, rx) = term_channel();
        let mut ports: Vec<TermPort> =
            (0..p).map(|ue| TermPort::new(ue, pc_max, tx.clone())).collect();
        let mut mon = MonitorPort::new(p, rx);
        // phase 1: random converge/diverge streaks with interleaved
        // polls — the monitor must track announce/retract pairs
        // without wedging or double-counting
        let mut stopped = false;
        for _ in 0..400 {
            let ue = rng.range(0, p);
            ports[ue].on_round(rng.chance(0.6));
            if rng.chance(0.3) && mon.poll() {
                stopped = true;
                break;
            }
        }
        for (ue, port) in ports.iter().enumerate() {
            assert!(
                port.diverge_sent() <= port.converge_sent(),
                "trial {trial}: port {ue} retracted more than it announced"
            );
        }
        // phase 2 (liveness + re-announce): however tangled the
        // history, sustained local convergence everywhere must reach
        // STOP. A worker that announced, diverged, and failed to
        // re-announce — or a monitor whose log missed a retraction
        // reset — would wedge this forever.
        if !stopped {
            for _ in 0..=pc_max {
                for port in ports.iter_mut() {
                    port.on_round(true);
                }
            }
            assert!(
                mon.poll(),
                "trial {trial}: no STOP after global re-convergence (p={p}, pc_max={pc_max})"
            );
        }
        assert!(mon.state().stopped(), "trial {trial}: poll returned true without stopping");
        assert_eq!(
            mon.state().converged_count(),
            p,
            "trial {trial}: STOP with an incomplete convergence log"
        );
    }
}

#[test]
fn termination_protocol_stop_is_sound_across_shards_and_steal() {
    let mut rng = Rng::new(99);
    let tol = 1e-9;
    for &shards in &[1usize, 2, 4, 8] {
        for &steal in &[false, true] {
            let el = asyncpr::coordinator::load_edgelist("scaled:3000", 42)
                .expect("generator specs are infallible");
            let mut g = DeltaGraph::from_edgelist(&el);
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            let st = sp.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "warm converge (s={shards})");
            // a random churn epoch leaves real residual spread over
            // the shards, then one worker stalls mid-solve: the
            // protocol must wait the sleeper out, not stop over it
            let mut batch = UpdateBatch::default();
            for _ in 0..200 {
                let u = rng.range(0, g.n()) as u32;
                let v = rng.range(0, g.n()) as u32;
                batch.insert.push((u, v));
            }
            let delta = g.apply(&batch).unwrap();
            sp.begin_epoch();
            sp.apply_batch(&g, &delta);
            let stall = (shards >= 2).then(|| StallInjection {
                worker: shards - 1,
                after_rounds: 0,
                ms: 120,
            });
            let opts = PushThreadOptions {
                tol,
                term: TermMode::Protocol,
                steal,
                inject_stall: stall,
                ..Default::default()
            };
            let tm = run_threaded_push(&g, &mut sp, &opts);
            if shards == 1 {
                // the single-shard fast path is deterministic — no
                // monitor, no protocol traffic
                assert_eq!(tm.stop_cause, StopCause::Converged, "s=1 fast path");
                assert_eq!(tm.term_converge, 0);
            } else {
                assert_eq!(
                    tm.stop_cause,
                    StopCause::Protocol,
                    "s={shards} steal={steal}: residual {:.3e}",
                    tm.residual
                );
                assert!(
                    tm.term_converge >= shards as u64,
                    "s={shards}: every worker must announce before STOP, saw {}",
                    tm.term_converge
                );
            }
            // the acceptance invariant: the stop is sound — the exact
            // gather-time residual is under tol, mass intact
            assert!(
                tm.converged && tm.residual < tol,
                "s={shards} steal={steal}: unsound stop at residual {:.3e}",
                tm.residual
            );
            let mass = sp.mass();
            assert!((mass - 1.0).abs() < 1e-9, "s={shards} steal={steal}: mass {mass}");
        }
    }
}
