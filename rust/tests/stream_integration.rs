//! Property & integration tests for the evolving-graph `stream`
//! subsystem (seeded random campaigns, same style as proptests.rs —
//! every failure prints its trial seed).
//!
//! Invariants covered:
//!   * push diffusion converges to `power_method`'s vector within
//!     tolerance on random graphs (satellite requirement a);
//!   * incremental per-epoch ranks match from-scratch recomputation
//!     after EVERY update batch (satellite requirement b);
//!   * `DeltaGraph` snapshots stay structurally consistent with the
//!     `Csr` pipeline across arbitrary batches;
//!   * the epoch driver reports warm-start savings and power-method
//!     agreement end-to-end.

use asyncpr::coordinator::experiments::{self, StreamOptions};
use asyncpr::graph::generators::{self, churn_batch, ChurnParams};
use asyncpr::graph::{Csr, EdgeList};
use asyncpr::pagerank::{power_method, PagerankProblem, PowerOptions};
use asyncpr::stream::{power_method_f64, DeltaGraph, PushState, UpdateBatch};
use asyncpr::util::Rng;

fn l1_64(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

fn random_edgelist(rng: &mut Rng, n: usize) -> EdgeList {
    let m = rng.range(n, n * 6);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        el.push(rng.range(0, n) as u32, rng.range(0, n) as u32);
    }
    el
}

fn random_batch(rng: &mut Rng, g: &DeltaGraph) -> UpdateBatch {
    let n0 = g.n();
    let new_nodes = rng.range(0, 4);
    let n1 = n0 + new_nodes;
    let mut batch = UpdateBatch { new_nodes, ..Default::default() };
    for _ in 0..rng.range(0, 30) {
        batch
            .insert
            .push((rng.range(0, n1) as u32, rng.range(0, n1) as u32));
    }
    let mut edges = Vec::new();
    g.for_each_edge(|s, d| edges.push((s, d)));
    if !edges.is_empty() {
        for _ in 0..rng.range(0, 20) {
            batch.remove.push(edges[rng.range(0, edges.len())]);
        }
    }
    batch
}

#[test]
fn prop_push_converges_to_power_method_any_graph() {
    // requirement (a): the f64 push solver lands on the f32
    // power_method fixed point within f32 cross-precision tolerance
    let mut rng = Rng::new(301);
    for trial in 0..20 {
        let n = rng.range(20, 800);
        let el = random_edgelist(&mut rng, n);
        let g = DeltaGraph::from_edgelist(&el);
        let mut s = PushState::new(n, 0.85);
        s.begin_epoch();
        let st = s.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged, "trial {trial}");

        let problem = PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85);
        let pm = power_method(
            &problem,
            &PowerOptions { tol: 1e-7, max_iters: 50_000, record_residuals: false },
        );
        assert!(pm.converged, "trial {trial}");
        let d: f64 = s
            .ranks()
            .iter()
            .zip(&pm.x)
            .map(|(a, b)| (a - *b as f64).abs())
            .sum();
        // budget: f32 power tail (~tol·α/(1-α)) plus f32 rounding
        assert!(d < 1e-4, "trial {trial} (n={n}): push vs power_method L1 {d}");
    }
}

#[test]
fn prop_incremental_matches_scratch_after_every_batch() {
    // requirement (b): after EVERY batch the warm-started state equals
    // a from-scratch solve of the same snapshot to 1e-8 L1
    let mut rng = Rng::new(302);
    for trial in 0..8 {
        let n = rng.range(50, 500);
        let el = random_edgelist(&mut rng, n);
        let mut g = DeltaGraph::from_edgelist(&el);
        let mut inc = PushState::new(g.n(), 0.85);
        inc.begin_epoch();
        inc.solve(&g, 1e-11, u64::MAX);
        for round in 0..5 {
            let batch = random_batch(&mut rng, &g);
            let delta = g.apply(&batch).unwrap();
            inc.begin_epoch();
            inc.apply_batch(&g, &delta);
            inc.solve(&g, 1e-11, u64::MAX);

            let mut cold = PushState::new(g.n(), 0.85);
            cold.begin_epoch();
            cold.solve(&g, 1e-11, u64::MAX);
            let d = l1_64(inc.ranks(), cold.ranks());
            assert!(d < 1e-8, "trial {trial} round {round}: inc vs scratch {d}");

            let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 100_000);
            let dp = l1_64(inc.ranks(), &xref);
            assert!(dp < 1e-8, "trial {trial} round {round}: inc vs power {dp}");
        }
    }
}

#[test]
fn prop_delta_graph_snapshot_consistent_with_csr() {
    let mut rng = Rng::new(303);
    for trial in 0..20 {
        let n = rng.range(10, 300);
        let el = random_edgelist(&mut rng, n);
        let mut g = DeltaGraph::from_edgelist(&el);
        for _ in 0..3 {
            let batch = random_batch(&mut rng, &g);
            g.apply(&batch).unwrap();
        }
        let csr = g.to_csr().unwrap();
        csr.validate().unwrap();
        assert_eq!(csr.n(), g.n(), "trial {trial}");
        assert_eq!(csr.nnz(), g.m(), "trial {trial}");
        for u in 0..g.n() {
            assert_eq!(
                csr.outdeg()[u] as usize,
                g.outdeg(u),
                "trial {trial} node {u}"
            );
        }
        // roundtrip through the edge list is structurally lossless
        let rt = DeltaGraph::from_edgelist(&g.to_edgelist());
        assert_eq!(rt.m(), g.m(), "trial {trial}");
        for u in 0..g.n() {
            assert_eq!(rt.out(u), g.out(u), "trial {trial} node {u}");
        }
    }
}

#[test]
fn stream_epochs_driver_end_to_end() {
    // the `repro stream` acceptance shape at test scale: warm start
    // strictly cheaper on every update epoch, final ranks within 1e-8
    // of a fresh power-method run
    let opts = StreamOptions { epochs: 4, seed: 9, ..Default::default() };
    let rep = experiments::stream_epochs("scaled:3000", &opts).unwrap();
    assert_eq!(rep.rows.len(), 5);
    assert!(rep.rows[0].inc_pushes > 0);
    for r in &rep.rows[1..] {
        assert!(
            r.inc_pushes < r.scratch_pushes,
            "epoch {}: warm {} >= scratch {}",
            r.epoch,
            r.inc_pushes,
            r.scratch_pushes
        );
        assert!(r.l1_vs_power < 1e-8, "epoch {}: L1 {}", r.epoch, r.l1_vs_power);
        assert!(r.inserted + r.new_nodes > 0, "churn must do something");
    }
    assert!(rep.all_updates_cheaper);
    assert!(rep.final_l1_vs_power < 1e-8);
    // and meaningfully cheaper, not just strictly:
    assert!(
        rep.update_scratch_pushes as f64 / rep.update_inc_pushes as f64 > 2.0,
        "warm start saved too little: {} vs {}",
        rep.update_inc_pushes,
        rep.update_scratch_pushes
    );
}

#[test]
fn stream_epochs_with_threads_meets_acceptance() {
    // the `repro stream --threads 4` path: each epoch scatters the warm
    // state into 4 balanced-nnz shards, drains on real threads, gathers
    // and polishes — the acceptance shape must hold despite the
    // nondeterministic schedule, because the gathered state is exact
    let opts = StreamOptions { epochs: 3, seed: 9, threads: 4, ..Default::default() };
    let rep = experiments::stream_epochs("scaled:3000", &opts).unwrap();
    assert_eq!(rep.rows.len(), 4);
    for r in &rep.rows {
        assert!(r.l1_vs_power < 1e-8, "epoch {}: L1 {}", r.epoch, r.l1_vs_power);
    }
    assert!(rep.final_l1_vs_power < 1e-8);
    // warm epochs stay far cheaper than from-scratch even counting the
    // staleness-inflated parallel pushes (aggregate: per-epoch counts
    // wobble with the schedule)
    assert!(
        rep.update_scratch_pushes as f64 / rep.update_inc_pushes.max(1) as f64 > 2.0,
        "threaded warm start saved too little: {} vs {}",
        rep.update_inc_pushes,
        rep.update_scratch_pushes
    );
}

#[test]
fn stream_epochs_deterministic() {
    let opts = StreamOptions { epochs: 2, seed: 11, ..Default::default() };
    let a = experiments::stream_epochs("scaled:1500", &opts).unwrap();
    let b = experiments::stream_epochs("scaled:1500", &opts).unwrap();
    assert_eq!(a.rows.len(), b.rows.len());
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.inc_pushes, rb.inc_pushes);
        assert_eq!(ra.scratch_pushes, rb.scratch_pushes);
        assert_eq!(ra.m, rb.m);
        assert_eq!(ra.l1_vs_power, rb.l1_vs_power);
    }
}

#[test]
fn churned_web_stays_web_like() {
    // after heavy churn the snapshot still feeds the whole static
    // stack: CSR validates, power method converges in a sane band
    let el = generators::power_law_web(&generators::WebParams::scaled(3_000), 5);
    let mut g = DeltaGraph::from_edgelist(&el);
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(13);
    for _ in 0..10 {
        let batch = churn_batch(&g, &churn, &mut rng);
        g.apply(&batch).unwrap();
    }
    let csr = g.to_csr().unwrap();
    csr.validate().unwrap();
    let problem = PagerankProblem::new(csr, 0.85);
    let pm = power_method(&problem, &PowerOptions::default());
    assert!(pm.converged);
    assert!(pm.iters < 200, "churn degenerated the graph: {} iters", pm.iters);
}
