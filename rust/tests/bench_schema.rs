//! Schema tests for the committed machine-readable bench trajectory
//! files (`benches/BENCH_*.json`, written by the `push_parallel`,
//! `topk_stream`, `ppr_serve`, `net_push`, and `giant_graph` benches
//! when `ASYNCPR_BENCH_JSON_DIR` is set).
//!
//! The committed files may be the pending placeholders (all-null
//! metric slots, a `note` explaining how to regenerate) or a real
//! measured run — the schema admits both, so the tests check shape and
//! key presence, with every metric slot number-or-null.

use asyncpr::util::Json;

fn load(name: &str) -> Json {
    let path = format!("{}/../benches/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("parse {path}: {e}"))
}

fn lookup<'a>(doc: &'a Json, path: &[&str]) -> &'a Json {
    let mut cur = doc;
    for k in path {
        cur = cur.get(k).unwrap_or_else(|| panic!("missing key {path:?}"));
    }
    cur
}

/// A metric slot holds a number once measured, null while pending.
fn num_or_null(doc: &Json, path: &[&str]) {
    let v = lookup(doc, path);
    assert!(
        matches!(v, Json::Num(_) | Json::Null),
        "{path:?} must be number or null, got {v:?}"
    );
}

fn common_header(doc: &Json, bench: &str) {
    assert_eq!(lookup(doc, &["schema"]).as_usize(), Some(1), "schema version");
    assert_eq!(lookup(doc, &["bench"]).as_str(), Some(bench), "bench name");
    let graph = lookup(doc, &["graph"]);
    assert!(matches!(graph, Json::Str(_) | Json::Null), "graph must be string or null");
    let quick = lookup(doc, &["quick"]);
    assert!(matches!(quick, Json::Bool(_) | Json::Null), "quick must be bool or null");
}

#[test]
fn push_parallel_trajectory_schema() {
    let doc = load("BENCH_push_parallel.json");
    common_header(&doc, "push_parallel");
    let scaling = lookup(&doc, &["scaling"]).as_arr().expect("scaling must be an array");
    for row in scaling {
        for key in ["shards", "wall_ms", "pushes", "fragments", "speedup", "residual"] {
            assert!(
                matches!(row.get(key), Some(Json::Num(_))),
                "scaling rows are always measured; missing/non-number {key}"
            );
        }
    }
    for side in ["roundtrip", "resident"] {
        for key in ["pushes", "csr_rows", "work", "wall_ms"] {
            num_or_null(&doc, &["resident_race", side, key]);
        }
    }
    for key in ["makespan", "idle_rounds", "wall_ms"] {
        num_or_null(&doc, &["steal_race", "static", key]);
        num_or_null(&doc, &["steal_race", "steal", key]);
    }
    num_or_null(&doc, &["steal_race", "steal", "stolen_rows"]);
    num_or_null(&doc, &["steal_race", "steal", "grants"]);
    for side in ["quiet", "protocol"] {
        let stop = lookup(&doc, &["term_race", side, "stop"]);
        assert!(matches!(stop, Json::Str(_) | Json::Null), "stop must be string or null");
        let conv = lookup(&doc, &["term_race", side, "converged"]);
        assert!(matches!(conv, Json::Bool(_) | Json::Null), "converged must be bool or null");
        for key in ["wall_ms", "pushes", "residual"] {
            num_or_null(&doc, &["term_race", side, key]);
        }
    }
    num_or_null(&doc, &["term_race", "protocol", "converge_msgs"]);
    num_or_null(&doc, &["term_race", "protocol", "diverge_msgs"]);
}

#[test]
fn topk_stream_trajectory_schema() {
    let doc = load("BENCH_topk_stream.json");
    common_header(&doc, "topk_stream");
    num_or_null(&doc, &["epochs"]);
    num_or_null(&doc, &["k"]);
    for key in ["pushes", "epochs_certified", "wall_ms"] {
        num_or_null(&doc, &["certified", key]);
    }
    num_or_null(&doc, &["full", "pushes"]);
    num_or_null(&doc, &["full", "wall_ms"]);
    num_or_null(&doc, &["push_saving"]);
}

#[test]
fn net_push_trajectory_schema() {
    let doc = load("BENCH_net_push.json");
    common_header(&doc, "net_push");
    num_or_null(&doc, &["shards"]);
    num_or_null(&doc, &["lag_ms"]);
    let stop = lookup(&doc, &["async", "stop"]);
    assert!(matches!(stop, Json::Str(_) | Json::Null), "stop must be string or null");
    let conv = lookup(&doc, &["async", "converged"]);
    assert!(matches!(conv, Json::Bool(_) | Json::Null), "converged must be bool or null");
    for key in ["wall_ms", "pushes", "fragments", "residual", "converge_msgs", "diverge_msgs"] {
        num_or_null(&doc, &["async", key]);
    }
    for key in ["rounds", "pushes", "fragments", "compute_ms", "charged_wire_ms", "wall_ms"] {
        num_or_null(&doc, &["barrier", key]);
    }
    num_or_null(&doc, &["speedup"]);
}

#[test]
fn giant_graph_trajectory_schema() {
    let doc = load("BENCH_giant_graph.json");
    common_header(&doc, "giant_graph");
    for key in ["scale", "edge_factor", "n", "m_requested", "nnz"] {
        num_or_null(&doc, &[key]);
    }
    let compact = lookup(&doc, &["compact_rowptr"]);
    assert!(
        matches!(compact, Json::Bool(_) | Json::Null),
        "compact_rowptr must be bool or null"
    );
    for key in [
        "write_ms",
        "build_ms",
        "csr_heap_bytes",
        "csr_heap_bytes_wide",
        "edgelist_bytes",
        "dense_estimate_bytes",
        "peak_rss_bytes",
    ] {
        num_or_null(&doc, &["build", key]);
    }
    for key in ["threads", "epochs", "pushes", "wall_ms", "pushes_per_sec"] {
        num_or_null(&doc, &["churn", key]);
    }
}

#[test]
fn ppr_serve_trajectory_schema() {
    let doc = load("BENCH_ppr_serve.json");
    common_header(&doc, "ppr_serve");
    num_or_null(&doc, &["rounds"]);
    num_or_null(&doc, &["queries"]);
    for key in ["pushes", "hit_rate", "p50_us", "p99_us", "wall_ms"] {
        num_or_null(&doc, &["warm", key]);
    }
    for key in ["pushes", "p50_us", "p99_us", "wall_ms"] {
        num_or_null(&doc, &["cold", key]);
    }
    num_or_null(&doc, &["push_saving"]);
}
