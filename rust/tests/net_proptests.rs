//! Property suite for the process-boundary wire protocol (seeded
//! random campaigns, same style as proptests.rs — the offline build
//! carries no proptest crate, so generators are explicit).
//!
//! Invariants covered:
//!   * every wire message kind survives encode → decode bit-for-bit,
//!     including subnormal/extreme f64 mass, empty fragments, and
//!     max-width ids (checked by re-encoding the decoded message and
//!     comparing raw frames — the codec is canonical);
//!   * the decoder is total: truncation at every cut, bad
//!     magic/version/kind, checksum damage, NaN mass, and arbitrary
//!     single-byte corruption all come back as [`WireError`]s, never
//!     panics or silent acceptance;
//!   * the fault-injection soundness matrix: shard counts 1/2/4/8 ×
//!     steal on/off × protocol/quiet over the throttled loopback with
//!     one stalled peer and per-link jitter — the gathered state
//!     conserves mass to 1e-9 and lands within 1e-9 L1 of a fresh
//!     power reference, and a protocol STOP implies the exact
//!     gather-time residual is under tol;
//!   * the regression the wire tier exists to expose: under an
//!     injected 200 ms link delay the quiet-window heuristic stops
//!     prematurely (mass still in flight), while the §4.2 protocol
//!     waits the wire out and stops soundly.

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions, StopCause, TermMode};
use asyncpr::net::codec::{decode, encode, peek, HEADER_LEN, TRAILER_LEN, WIRE_VERSION};
use asyncpr::net::{
    FaultPlan, LinkFault, NetConfig, PeerStall, WireError, WireHeadFrame, WireMsg, WireRow,
};
use asyncpr::stream::{power_method_f64, DeltaGraph, ResidualFragment, ShardedPush, UpdateBatch};
use asyncpr::termination::TermMsg;
use asyncpr::util::Rng;

/// FNV-1a-32 as specified in the frame layout docs — reimplemented
/// here so corruption tests can re-stamp a damaged frame's checksum
/// and prove the *semantic* validators fire, not just the checksum.
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn restamp(frame: &mut [u8]) {
    let body_end = frame.len() - TRAILER_LEN;
    let sum = fnv1a32(&frame[..body_end]).to_le_bytes();
    frame[body_end..].copy_from_slice(&sum);
}

/// Mass values biased toward the representations that shake out
/// lossy serialization: signed zeros, subnormals, extremes.
fn wild_mass(rng: &mut Rng) -> f64 {
    match rng.range(0, 9) {
        0 => 0.0,
        1 => -0.0,
        2 => f64::MIN_POSITIVE,       // smallest normal
        3 => f64::MIN_POSITIVE / 4.0, // subnormal
        4 => 5e-324,                  // smallest subnormal
        5 => f64::MAX,
        6 => -f64::MAX,
        7 => 1e-300,
        _ => rng.f64() * 2.0 - 1.0,
    }
}

fn wild_id(rng: &mut Rng) -> u32 {
    match rng.range(0, 4) {
        0 => 0,
        1 => u32::MAX,
        2 => u32::MAX - 1,
        _ => rng.range(0, 1 << 20) as u32,
    }
}

fn wild_u64(rng: &mut Rng) -> u64 {
    match rng.range(0, 3) {
        0 => 0,
        1 => u64::MAX,
        _ => rng.range(0, usize::MAX) as u64,
    }
}

fn random_frag(rng: &mut Rng) -> ResidualFragment {
    let n = rng.range(0, 5); // 0 = the empty fragment
    ResidualFragment {
        entries: (0..n).map(|_| (wild_id(rng), wild_mass(rng))).collect(),
        uni: wild_mass(rng),
        pv: wild_mass(rng),
    }
}

/// One random message drawn uniformly over all ten wire kinds.
fn random_msg(rng: &mut Rng) -> WireMsg {
    match rng.range(0, 10) {
        0 => WireMsg::Frag { src: wild_id(rng), frag: random_frag(rng) },
        1 => WireMsg::StealRequest { thief: wild_id(rng) },
        2 => WireMsg::Grant {
            src: wild_id(rng),
            rows: (0..rng.range(0, 4))
                .map(|_| WireRow {
                    node: wild_id(rng),
                    p: wild_mass(rng),
                    r: wild_mass(rng),
                    touched: rng.chance(0.5),
                })
                .collect(),
        },
        3 => WireMsg::HeadFrame {
            src: wild_id(rng),
            gen: wild_u64(rng),
            frame: WireHeadFrame {
                entries: (0..rng.range(0, 4)).map(|_| (wild_id(rng), wild_mass(rng))).collect(),
                // -inf is the one infinity the protocol legitimately
                // produces (pool covers the whole shard)
                rest_bound: if rng.chance(0.3) { f64::NEG_INFINITY } else { wild_mass(rng) },
                r_plus: wild_mass(rng),
                r_minus: wild_mass(rng),
                unk_plus: wild_mass(rng),
                unk_minus: wild_mass(rng),
            },
        },
        4 => WireMsg::Term {
            src: wild_id(rng),
            msg: [TermMsg::Converge, TermMsg::Diverge, TermMsg::Stop][rng.range(0, 3)],
            inflight: (0..rng.range(0, 4)).map(|_| (wild_id(rng), wild_u64(rng))).collect(),
        },
        5 => WireMsg::Hello { shard: wild_id(rng) },
        6 => WireMsg::Ack { peer: wild_id(rng) },
        7 => WireMsg::Flushed { src: wild_id(rng) },
        8 => WireMsg::DumpReq,
        _ => WireMsg::State {
            src: wild_id(rng),
            lo: wild_id(rng),
            p: (0..rng.range(0, 6)).map(|_| wild_mass(rng)).collect(),
            r: (0..rng.range(0, 6)).map(|_| wild_mass(rng)).collect(),
            uni: wild_mass(rng),
            pv: wild_mass(rng),
            pushes: wild_u64(rng),
        },
    }
}

#[test]
fn net_codec_random_round_trips_bit_exact() {
    let mut rng = Rng::new(0xC0DEC);
    for trial in 0..600 {
        let msg = random_msg(&mut rng);
        let dst = rng.range(0, u16::MAX as usize + 1) as u16;
        let bytes = encode(&msg, dst);
        let (_, pdst, total) = peek(&bytes).expect("peek on a fresh frame");
        assert_eq!(pdst, dst, "trial {trial}: peek dst");
        assert_eq!(total, bytes.len(), "trial {trial}: peek length");
        let (got, gdst, used) = decode(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: decode of {msg:?} failed: {e}"));
        assert_eq!(gdst, dst, "trial {trial}: decode dst");
        assert_eq!(used, bytes.len(), "trial {trial}: decode consumed");
        // the codec is canonical, so byte-identical re-encoding IS the
        // bit-for-bit check — it covers every f64 payload bit (signed
        // zeros and subnormals included) without per-variant matching
        let again = encode(&got, dst);
        assert_eq!(again, bytes, "trial {trial}: round trip not bit-exact for {msg:?}");
    }
}

#[test]
fn net_codec_truncation_rejected_at_every_cut() {
    let mut rng = Rng::new(0x7121);
    for trial in 0..40 {
        let bytes = encode(&random_msg(&mut rng), rng.range(0, 64) as u16);
        for cut in 0..bytes.len() {
            assert!(
                matches!(decode(&bytes[..cut]), Err(WireError::Truncated)),
                "trial {trial}: cut at {cut}/{} not reported as truncation",
                bytes.len()
            );
        }
    }
}

#[test]
fn net_codec_header_and_checksum_damage_rejected() {
    let mut rng = Rng::new(0xBAD);
    for _ in 0..60 {
        let good = encode(&random_msg(&mut rng), rng.range(0, 64) as u16);
        let mut b = good.clone();
        b[0] ^= 0x01;
        assert!(matches!(decode(&b), Err(WireError::BadMagic)));
        let mut b = good.clone();
        b[2] = WIRE_VERSION + 1 + rng.range(0, 200) as u8;
        assert!(matches!(decode(&b), Err(WireError::BadVersion(_))));
        let mut b = good.clone();
        b[3] = 10 + rng.range(0, 200) as u8; // past the last kind
        assert!(matches!(decode(&b), Err(WireError::BadKind(_))));
        let mut b = good.clone();
        let at = b.len() - 1 - rng.range(0, TRAILER_LEN);
        b[at] ^= 0xFF;
        assert!(matches!(decode(&b), Err(WireError::BadChecksum)));
    }
}

#[test]
fn net_codec_single_byte_corruption_never_accepted_or_panics() {
    // every single-byte change lands inside the checksummed span or
    // the checksum itself, so decode must error — and must never
    // panic, whatever the damaged bytes claim
    let mut rng = Rng::new(0xF11);
    for trial in 0..200 {
        let good = encode(&random_msg(&mut rng), rng.range(0, 64) as u16);
        let mut b = good.clone();
        let at = rng.range(0, b.len());
        let flip = 1u8 << rng.range(0, 8);
        b[at] ^= flip;
        assert!(
            decode(&b).is_err(),
            "trial {trial}: byte {at} flipped by {flip:#x} still decoded"
        );
    }
}

#[test]
fn net_codec_nan_mass_rejected_after_restamp() {
    // write NaN into every mass field of a fragment frame in turn and
    // re-stamp the checksum, so only the NaN validator can object
    let frag = ResidualFragment { entries: vec![(3, 0.5), (9, 0.25)], uni: 1e-3, pv: 2e-3 };
    let good = encode(&WireMsg::Frag { src: 1, frag }, 2);
    let nan = f64::NAN.to_bits().to_le_bytes();
    // payload layout: src u32, uni f64, pv f64, count u32, then
    // (node u32, mass f64) pairs
    let mass_offsets =
        [HEADER_LEN + 4, HEADER_LEN + 12, HEADER_LEN + 24 + 4, HEADER_LEN + 36 + 4];
    for &at in &mass_offsets {
        let mut b = good.clone();
        b[at..at + 8].copy_from_slice(&nan);
        restamp(&mut b);
        assert!(
            matches!(decode(&b), Err(WireError::NanMass)),
            "NaN at offset {at} not rejected"
        );
    }
}

#[test]
fn net_codec_lying_counts_rejected_after_restamp() {
    let mut rng = Rng::new(0x11E5);
    for _ in 0..60 {
        // an empty fragment's count field sits right after src+uni+pv
        let mut b = encode(
            &WireMsg::Frag {
                src: 0,
                frag: ResidualFragment { entries: vec![], uni: 0.0, pv: 0.0 },
            },
            0,
        );
        let lie = (rng.range(1, u32::MAX as usize) as u32).to_le_bytes();
        b[HEADER_LEN + 20..HEADER_LEN + 24].copy_from_slice(&lie);
        restamp(&mut b);
        assert!(matches!(decode(&b), Err(WireError::Malformed(_))));
    }
}

/// Shared scenario builder: a converged sharded state plus one churn
/// batch of fresh residual, the workload every soundness cell drains
/// over the throttled wire.
fn churned_state(shards: usize, rng: &mut Rng) -> (DeltaGraph, ShardedPush) {
    let el = asyncpr::coordinator::load_edgelist("scaled:2000", 42)
        .expect("generator specs are infallible");
    let mut g = DeltaGraph::from_edgelist(&el);
    let mut sp = ShardedPush::new(&g, 0.85, shards);
    let st = sp.solve(&g, 1e-11, u64::MAX);
    assert!(st.converged, "warm converge (s={shards})");
    let mut batch = UpdateBatch::default();
    for _ in 0..150 {
        let u = rng.range(0, g.n()) as u32;
        let v = rng.range(0, g.n()) as u32;
        batch.insert.push((u, v));
    }
    let delta = g.apply(&batch).unwrap();
    sp.begin_epoch();
    sp.apply_batch(&g, &delta);
    (g, sp)
}

#[test]
fn net_loopback_fault_matrix_stop_is_sound() {
    let mut rng = Rng::new(4242);
    let tol = 1e-10;
    for &shards in &[1usize, 2, 4, 8] {
        for &steal in &[false, true] {
            for &quiet in &[false, true] {
                let (g, mut sp) = churned_state(shards, &mut rng);
                // one stalled peer plus heavy jitter on every link —
                // the schedule that races retractions against releases
                let mut cfg = NetConfig::test(shards + 1);
                cfg.seed = 0xFA17 ^ ((shards as u64) << 2) ^ ((steal as u64) << 1) ^ quiet as u64;
                cfg.faults.link_faults.push(LinkFault {
                    src: None,
                    dst: None,
                    delay: 0.0,
                    jitter: 0.002,
                });
                if shards >= 2 {
                    cfg.faults.stalls.push(PeerStall {
                        peer: shards - 1,
                        start: 0.0,
                        duration: 0.030,
                    });
                }
                let opts = PushThreadOptions {
                    tol,
                    steal: steal && shards >= 2,
                    term: if quiet { TermMode::Quiet } else { TermMode::Protocol },
                    net: Some(cfg),
                    ..Default::default()
                };
                let tm = run_threaded_push(&g, &mut sp, &opts);
                let tag = format!("s={shards} steal={steal} quiet={quiet}");
                // mass survives the wire regardless of how the run
                // stopped: Σp + R/(1-α) must still be the full unit
                let mass = sp.mass();
                assert!((mass - 1.0).abs() < 1e-9, "{tag}: mass drifted to {mass}");
                if !quiet && shards >= 2 {
                    // a protocol STOP is a sound stop — exact residual
                    // under tol at gather time, no polish allowed
                    assert_eq!(
                        tm.stop_cause,
                        StopCause::Protocol,
                        "{tag}: residual {:.3e}",
                        tm.residual
                    );
                    let exact = sp.residual_recompute();
                    assert!(
                        tm.converged && exact < tol,
                        "{tag}: unsound protocol stop at exact residual {exact:.3e}"
                    );
                } else if !quiet {
                    // single-shard fast path: deterministic drain
                    assert_eq!(tm.stop_cause, StopCause::Converged, "{tag}");
                } else {
                    // the quiet heuristic may stop early over a wire —
                    // that premature-stop is pinned down by the
                    // regression test below; here finish the drain so
                    // the accuracy bar applies to every cell
                    let st = sp.solve(&g, tol, u64::MAX);
                    assert!(st.converged, "{tag}: polish hit the budget");
                }
                let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 200_000);
                let l1: f64 =
                    sp.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
                assert!(l1 < 1e-9, "{tag}: gathered ranks {l1:.3e} from the power reference");
            }
        }
    }
}

#[test]
fn net_link_delay_quiet_premature_protocol_sound() {
    // the scenario from the issue: one shard's outbound links carry an
    // injected 200 ms delay. Churn lands almost entirely in that
    // shard, it drains fast (local estimate under tol), and the moved
    // mass crawls the wire. The quiet window sees every published
    // estimate quiet and stops with the mass still in flight; the
    // §4.2 protocol holds CONVERGE back until every fragment is
    // acknowledged, so it waits the wire out.
    let shards = 4;
    let tol = 1e-10;
    for &quiet in &[true, false] {
        let el = asyncpr::coordinator::load_edgelist("scaled:2000", 42)
            .expect("generator specs are infallible");
        let mut g = DeltaGraph::from_edgelist(&el);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        assert!(sp.solve(&g, 1e-11, u64::MAX).converged, "warm converge");
        let n = g.n();
        let mut rng = Rng::new(77);
        let mut batch = UpdateBatch::default();
        for _ in 0..300 {
            // sources in the top eighth of the row space — inside the
            // last shard's home range; targets in the bottom half, so
            // the pushed mass must leave over the delayed links
            let u = rng.range(7 * n / 8, n) as u32;
            let v = rng.range(0, n / 2) as u32;
            batch.insert.push((u, v));
        }
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(&g, &delta);
        let mut cfg = NetConfig::test(shards + 1);
        cfg.faults = FaultPlan::delay_from(shards - 1, 200.0, 0.0);
        let opts = PushThreadOptions {
            tol,
            term: if quiet { TermMode::Quiet } else { TermMode::Protocol },
            net: Some(cfg),
            ..Default::default()
        };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        // whichever way it stopped, the in-flight mass was recovered
        // at gather time — premature means early, never lossy
        let mass = sp.mass();
        assert!((mass - 1.0).abs() < 1e-9, "quiet={quiet}: mass drifted to {mass}");
        let exact = sp.residual_recompute();
        if quiet {
            assert_eq!(tm.stop_cause, StopCause::QuietWindow, "quiet must fire first");
            assert!(
                exact > tol,
                "quiet under a 200 ms link delay must be premature, \
                 but gather-time residual is {exact:.3e}"
            );
        } else {
            assert_eq!(
                tm.stop_cause,
                StopCause::Protocol,
                "protocol must outwait the wire (residual {:.3e})",
                tm.residual
            );
            assert!(
                tm.converged && exact < tol,
                "protocol stop left residual {exact:.3e} >= tol"
            );
        }
    }
}
