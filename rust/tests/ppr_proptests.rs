//! Invariant suite for the personalized-teleport generalization and
//! the serving tier (seeded random campaigns, same style as
//! topk_proptests.rs — every failure names its trial/round).
//!
//! Invariants covered:
//!   * an *explicit uniform* personalization vector reproduces the
//!     global path bit-for-bit in the limit: ranks agree to 1e-12 L1
//!     on the sequential, sharded, and threaded backends, for both
//!     dangling policies (uniform `v` makes them identical);
//!   * the serving tier's incremental cache invalidation is sound:
//!     answers served from a cached-then-churned state match a cold
//!     personalized solve on the same snapshot to 1e-9, across 50
//!     random churn batches;
//!   * a churned warm state never reports convergence with a residual
//!     above the tier tolerance (the certificate's precondition).
//!
//! Every test name starts with `ppr_`: CI's debug pass skips them and
//! the release pass (with `-C debug-assertions`) runs the whole file.

use std::sync::Arc;

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::graph::generators;
use asyncpr::stream::{
    DeltaGraph, Personalization, PushState, ServeOptions, ServeTier, ShardedPush, UpdateBatch,
};
use asyncpr::util::Rng;

fn web(n: usize, seed: u64) -> DeltaGraph {
    let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
    DeltaGraph::from_edgelist(&el)
}

/// Random edge churn *without node arrivals*: a fixed uniform `v` over
/// the initial nodes only stays equal to the global `e/n` teleport
/// while `n` is constant, so the equivalence tests churn edges only.
fn edge_batch(rng: &mut Rng, g: &DeltaGraph) -> UpdateBatch {
    let n = g.n();
    let mut b = UpdateBatch::default();
    for _ in 0..rng.range(1, 25) {
        b.insert.push((rng.range(0, n) as u32, rng.range(0, n) as u32));
    }
    let mut edges = Vec::new();
    g.for_each_edge(|s, d| edges.push((s, d)));
    if !edges.is_empty() {
        for _ in 0..rng.range(0, 12) {
            b.remove.push(edges[rng.range(0, edges.len())]);
        }
    }
    b
}

/// Full churn (arrivals allowed) for the serving-tier soundness test —
/// the sources live in the initial id range, so they stay valid.
fn full_batch(rng: &mut Rng, g: &DeltaGraph) -> UpdateBatch {
    let n0 = g.n();
    let new_nodes = rng.range(0, 3);
    let n1 = n0 + new_nodes;
    let mut b = UpdateBatch { new_nodes, ..Default::default() };
    for _ in 0..rng.range(1, 20) {
        b.insert.push((rng.range(0, n1) as u32, rng.range(0, n1) as u32));
    }
    let mut edges = Vec::new();
    g.for_each_edge(|s, d| edges.push((s, d)));
    if !edges.is_empty() {
        for _ in 0..rng.range(0, 10) {
            b.remove.push(edges[rng.range(0, edges.len())]);
        }
    }
    b
}

fn l1(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// The solves run to 1e-14, so each backend's rank error is bounded by
/// `tol/(1-α) ≈ 6.7e-14`; 1e-12 leaves an order of magnitude of slack.
const SOLVE_TOL: f64 = 1e-14;
const MATCH_TOL: f64 = 1e-12;

#[test]
fn ppr_uniform_v_matches_global_path_on_state_backend() {
    for (trial, &dangling_to_v) in [false, true].iter().enumerate() {
        let mut g = web(350 + 40 * trial, 9_000 + trial as u64);
        let mut rng = Rng::new(9_100 + trial as u64);
        let mut global = PushState::new(g.n(), 0.85);
        let pers = Arc::new(Personalization::uniform(g.n(), dangling_to_v));
        let mut pprs = PushState::new_personalized(g.n(), 0.85, Arc::clone(&pers));
        for round in 0..5 {
            if round > 0 {
                let batch = edge_batch(&mut rng, &g);
                let delta = g.apply(&batch).unwrap();
                global.begin_epoch();
                global.apply_batch(&g, &delta);
                pprs.begin_epoch();
                pprs.apply_batch(&g, &delta);
            } else {
                global.begin_epoch();
                pprs.begin_epoch();
            }
            assert!(global.solve(&g, SOLVE_TOL, u64::MAX).converged);
            assert!(pprs.solve(&g, SOLVE_TOL, u64::MAX).converged);
            let d = l1(global.ranks(), pprs.ranks());
            assert!(
                d <= MATCH_TOL,
                "trial {trial} (dangling_to_v={dangling_to_v}) round {round}: \
                 uniform-v PPR differs from global by {d:.2e}"
            );
        }
    }
}

#[test]
fn ppr_uniform_v_matches_global_path_on_sharded_backend() {
    for (trial, shards) in [1usize, 2, 3, 5].into_iter().enumerate() {
        let dangling_to_v = trial % 2 == 0;
        let mut g = web(300 + 50 * trial, 9_300 + trial as u64);
        let mut rng = Rng::new(9_400 + trial as u64);
        let mut global = ShardedPush::new(&g, 0.85, shards);
        let pers = Arc::new(Personalization::uniform(g.n(), dangling_to_v));
        let mut pprs = ShardedPush::new_personalized(&g, 0.85, shards, Arc::clone(&pers));
        for round in 0..5 {
            if round > 0 {
                let batch = edge_batch(&mut rng, &g);
                let delta = g.apply(&batch).unwrap();
                global.begin_epoch();
                global.apply_batch(&g, &delta);
                pprs.begin_epoch();
                pprs.apply_batch(&g, &delta);
            } else {
                global.begin_epoch();
                pprs.begin_epoch();
            }
            assert!(global.solve(&g, SOLVE_TOL, u64::MAX).converged);
            assert!(pprs.solve(&g, SOLVE_TOL, u64::MAX).converged);
            let mt = pprs.target_mass();
            assert!(
                (pprs.mass() - mt).abs() < 1e-10,
                "trial {trial} round {round}: PPR mass {:.12} != target {mt:.12}",
                pprs.mass()
            );
            let d = l1(&global.ranks(), &pprs.ranks());
            assert!(
                d <= MATCH_TOL,
                "trial {trial} ({shards} shards, dangling_to_v={dangling_to_v}) \
                 round {round}: uniform-v PPR differs from global by {d:.2e}"
            );
        }
    }
}

#[test]
fn ppr_uniform_v_matches_global_path_on_threaded_backend() {
    for (trial, threads) in [2usize, 3, 4].into_iter().enumerate() {
        let dangling_to_v = trial % 2 == 1;
        let g = web(320 + 40 * trial, 9_600 + trial as u64);
        let mut global = PushState::new(g.n(), 0.85);
        global.begin_epoch();
        assert!(global.solve(&g, SOLVE_TOL, u64::MAX).converged);

        let pers = Arc::new(Personalization::uniform(g.n(), dangling_to_v));
        let mut sp = ShardedPush::new_personalized(&g, 0.85, threads, pers);
        sp.begin_epoch();
        let topts = PushThreadOptions { tol: SOLVE_TOL, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &topts);
        if !tm.converged {
            // the monitor may cut early (timeout/quiet race); the
            // deterministic polish is part of the backend's contract
            assert!(sp.solve(&g, SOLVE_TOL, u64::MAX).converged, "trial {trial}");
        }
        let d = l1(global.ranks(), &sp.ranks());
        assert!(
            d <= MATCH_TOL,
            "trial {trial} ({threads} threads, dangling_to_v={dangling_to_v}): \
             uniform-v PPR differs from global by {d:.2e}"
        );
    }
}

#[test]
fn ppr_cached_then_churned_answers_match_cold_solves() {
    // the tier answers from warm states that absorbed 50 random deltas
    // incrementally; every answer must match a cold personalized solve
    // on the *same* snapshot. Tier and cold solves both run to 1e-11,
    // so each score's error is ≤ tol/(1-α) ≈ 6.7e-11 and the scores may
    // differ by ≤ 1.4e-10 — 1e-9 is the acceptance bar with slack.
    let tol = 1e-11;
    let mut g = web(400, 10_000);
    let mut rng = Rng::new(10_100);
    let queries: Vec<Vec<u32>> = (0..4)
        .map(|_| rng.sample_distinct(g.n(), 3).into_iter().map(|u| u as u32).collect())
        .collect();
    let mut tier = ServeTier::new(ServeOptions { tol, topk: 12, ..Default::default() });
    // seed the cache so every later answer is a cached-then-churned one
    for q in &queries {
        tier.query(&g, q).unwrap();
    }
    for round in 0..50 {
        let batch = full_batch(&mut rng, &g);
        let delta = g.apply(&batch).unwrap();
        tier.apply_batch(&g, &delta);
        let q = &queries[rng.range(0, queries.len())];
        let ans = tier.query(&g, q).unwrap();
        assert!(ans.from_cache, "round {round}: warm state was dropped");
        assert!(
            ans.residual < tol,
            "round {round}: answer returned unconverged at {:.2e}",
            ans.residual
        );

        let pers = Arc::new(Personalization::sources(q).unwrap());
        let mut cold = PushState::new_personalized(g.n(), 0.85, pers);
        cold.begin_epoch();
        assert!(cold.solve(&g, tol, u64::MAX).converged, "round {round}");
        let xref = cold.ranks();
        for (i, (&node, &score)) in ans.head.iter().zip(&ans.scores).enumerate() {
            let want = xref[node as usize];
            assert!(
                (score - want).abs() <= 1e-9,
                "round {round}: head[{i}] = node {node} scored {score:.14} \
                 but the cold solve says {want:.14}"
            );
        }
    }
    let st = tier.stats();
    assert!(st.hit_rate() > 0.8, "cache should have served the rounds: {st:?}");
    assert!(
        st.warm_pushes < st.cold_pushes.max(1) * 50,
        "warm upkeep ({}) should not dwarf the cold builds ({})",
        st.warm_pushes,
        st.cold_pushes
    );
}
