//! Property suite for the `obs` telemetry subsystem (seeded random
//! campaigns, same style as proptests.rs — the offline build carries
//! no proptest crate, so generators are explicit).
//!
//! Invariants covered:
//!   * the event ring keeps exactly the most recent `cap` records in
//!     order, uncorrupted, with exact lifetime totals, for any
//!     (cap, event-count) shape;
//!   * a steal grant is always preceded by a matching steal request
//!     (thief asks on its track before the victim grants on its own),
//!     on the deterministic `steal_rows` path;
//!   * the Chrome-trace exporter round-trips through `util::json` with
//!     the track/name/counter structure intact;
//!   * a traced threaded run's final residual-decay samples sum to the
//!     reported `PushThreadMetrics.residual` (1e-9 — the acceptance
//!     contract), and every shard track records events;
//!   * tracing stays opt-in (`Default` solvers carry no collector) and
//!     the enabled path's overhead on the deterministic driver stays
//!     under a generous documented bound.

use std::sync::Arc;
use std::time::Instant;

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::obs::{Event, EventKind, EventRing, TraceCollector, KIND_COUNT};
use asyncpr::stream::{DeltaGraph, ShardedPush};
use asyncpr::util::{Json, Rng};

fn small_graph(spec: &str) -> DeltaGraph {
    let el = asyncpr::coordinator::load_edgelist(spec, 42).expect("generator specs are infallible");
    DeltaGraph::from_edgelist(&el)
}

#[test]
fn prop_ring_keeps_exact_recent_window_any_shape() {
    let mut rng = Rng::new(2024);
    for trial in 0..200 {
        let cap = rng.range(1, 64);
        let n = rng.range(0, 300) as u64;
        let ring = EventRing::new(cap);
        for i in 0..n {
            ring.record(Event {
                t_us: i,
                kind: EventKind::ALL[rng.range(0, KIND_COUNT)],
                a: i.wrapping_mul(0x9e37_79b9),
                v: i as f64 * 0.5,
            });
        }
        let evs = ring.snapshot();
        let expect_len = (n as usize).min(ring.capacity());
        assert_eq!(evs.len(), expect_len, "trial {trial}: window length");
        for (j, ev) in evs.iter().enumerate() {
            let i = n - expect_len as u64 + j as u64;
            assert_eq!(ev.t_us, i, "trial {trial}: slot {j} timestamp");
            assert_eq!(ev.a, i.wrapping_mul(0x9e37_79b9), "trial {trial}: slot {j} payload");
            assert_eq!(ev.v, i as f64 * 0.5, "trial {trial}: slot {j} value");
        }
        let totals = ring.totals();
        assert_eq!(totals.total(), n, "trial {trial}: lifetime total");
        assert_eq!(
            totals.dropped,
            n.saturating_sub(ring.capacity() as u64),
            "trial {trial}: dropped count"
        );
    }
}

#[test]
fn steal_grant_always_preceded_by_matching_request() {
    let g = small_graph("scaled:2000");
    for trial in 0..20 {
        let mut rng = Rng::new(7000 + trial);
        let shards = rng.range(2, 6);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        let tr = Arc::new(TraceCollector::default());
        sp.attach_trace(Arc::clone(&tr));
        // a cold state queues every row, so the victim always has
        // stealable residual and the grant path actually fires
        let victim = rng.range(0, shards);
        let thief = (victim + rng.range(1, shards)) % shards;
        let moved = sp.steal_rows(victim, thief, rng.range(1, 32));
        assert!(moved > 0, "trial {trial}: cold victim must have stealable rows");

        let grants: Vec<Event> = tr
            .events_for(victim)
            .into_iter()
            .filter(|e| e.kind == EventKind::StealGrant)
            .collect();
        assert_eq!(grants.len(), 1, "trial {trial}: one grant on the victim track");
        assert_eq!(grants[0].a, thief as u64, "trial {trial}: grant names the thief");
        assert_eq!(grants[0].v, moved as f64, "trial {trial}: grant carries the row count");
        let requests: Vec<Event> = tr
            .events_for(thief)
            .into_iter()
            .filter(|e| e.kind == EventKind::StealRequest && e.a == victim as u64)
            .collect();
        assert!(
            !requests.is_empty(),
            "trial {trial}: grant without a matching request on the thief track"
        );
        assert!(
            requests.iter().any(|r| r.t_us <= grants[0].t_us),
            "trial {trial}: request must not postdate its grant"
        );

        // the epoch-boundary return shows up on the monitor track
        let home = sp.repatriate();
        assert_eq!(home, moved, "trial {trial}: all stolen rows return home");
        assert_eq!(
            tr.monitor_totals().get(EventKind::Repatriate),
            1,
            "trial {trial}: repatriation recorded on the monitor track"
        );
    }
}

#[test]
fn deterministic_solve_emits_batches_and_decay_series() {
    let g = small_graph("scaled:1500");
    let mut sp = ShardedPush::new(&g, 0.85, 3);
    let tr = Arc::new(TraceCollector::default());
    sp.attach_trace(Arc::clone(&tr));
    let st = sp.solve(&g, 1e-9, u64::MAX);
    assert!(st.converged, "cold solve must converge");

    let batches: u64 =
        (0..tr.shard_tracks()).map(|i| tr.totals_for(i).get(EventKind::PushBatch)).sum();
    assert!(batches > 0, "a converging solve must record push batches");
    let samples = tr.samples();
    assert!(!samples.is_empty(), "superstep loop must emit the decay series");
    // the series decays: last sweep's total residual is under tol,
    // first sweep's is macroscopic (a cold state holds ~unit mass)
    let first_t = samples[0].t_us;
    let first_total: f64 =
        samples.iter().filter(|s| s.t_us == first_t).map(|s| s.residual).sum();
    let final_total: f64 =
        tr.final_samples().iter().flatten().map(|s| s.residual).sum();
    assert!(first_total > 1e-3, "first sweep should see the cold residual, got {first_total:e}");
    assert!(final_total < 2e-9, "final sweep must sit at convergence, got {final_total:e}");
    assert!((final_total - st.residual).abs() < 1e-9, "series tail vs reported residual");
}

#[test]
fn chrome_export_structure_survives_json_roundtrip() {
    let g = small_graph("scaled:1500");
    let mut sp = ShardedPush::new(&g, 0.85, 2);
    let tr = Arc::new(TraceCollector::default());
    sp.attach_trace(Arc::clone(&tr));
    sp.solve(&g, 1e-9, u64::MAX);

    let text = tr.to_chrome_json().to_string_compact();
    let parsed = Json::parse(&text).expect("exporter emits valid JSON");
    let evs = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!evs.is_empty());
    let shards = tr.shard_tracks();
    let mut counters = 0usize;
    let mut instants = 0usize;
    for ev in evs {
        assert_eq!(ev.get("pid").and_then(Json::as_usize), Some(0));
        match ev.get("ph").and_then(Json::as_str).expect("every event has a phase") {
            "M" => {}
            "i" => {
                instants += 1;
                let name = ev.get("name").and_then(Json::as_str).unwrap();
                assert!(
                    EventKind::ALL.iter().any(|k| k.name() == name),
                    "instant name {name:?} is not an EventKind"
                );
                let tid = ev.get("tid").and_then(Json::as_usize).unwrap();
                assert!(tid <= shards, "tid {tid} beyond the monitor track");
            }
            "C" => counters += 1,
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(instants > 0, "solve events must appear as instants");
    let series = parsed.get("series").and_then(Json::as_arr).expect("series array");
    assert_eq!(counters, series.len(), "one counter event per series sample");
    assert_eq!(
        parsed.get("sampleIntervalUs").and_then(Json::as_usize),
        Some(tr.sample_interval_us() as usize)
    );
}

#[test]
fn threaded_trace_series_tail_matches_metrics_residual() {
    let g = small_graph("scaled:2500");
    let shards = 3usize;
    let mut sp = ShardedPush::new(&g, 0.85, shards);
    let tr = Arc::new(TraceCollector::default());
    let opts = PushThreadOptions { tol: 1e-9, trace: Some(Arc::clone(&tr)), ..Default::default() };
    let tm = run_threaded_push(&g, &mut sp, &opts);

    let events = tm.events.as_ref().expect("traced run must report event totals");
    assert_eq!(events.len(), shards);
    for (i, totals) in events.iter().enumerate() {
        assert!(totals.total() > 0, "shard track {i} recorded no events");
    }
    let finals = tr.final_samples();
    assert_eq!(finals.len(), shards, "one final sample per shard");
    let tail: f64 = finals.iter().map(|s| s.expect("every shard sampled").residual).sum();
    // the acceptance contract: the post-run per-shard samples are taken
    // from the same exact re-tally the metrics residual sums
    assert!(
        (tail - tm.residual).abs() < 1e-9,
        "series tail {tail:e} vs metrics residual {:e}",
        tm.residual
    );
}

#[test]
fn tracing_stays_opt_in_and_enabled_overhead_is_bounded() {
    assert!(PushThreadOptions::default().trace.is_none(), "tracing must be opt-in");
    let g = small_graph("scaled:2000");
    assert!(
        ShardedPush::new(&g, 0.85, 2).trace_handle().is_none(),
        "solvers must build untraced"
    );

    let solve_wall = |traced: bool| {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let mut sp = ShardedPush::new(&g, 0.85, 2);
            if traced {
                sp.attach_trace(Arc::new(TraceCollector::default()));
            }
            let t0 = Instant::now();
            let st = sp.solve(&g, 1e-9, u64::MAX);
            assert!(st.converged);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let plain = solve_wall(false);
    let traced = solve_wall(true);
    // documented bound (ARCHITECTURE.md "Observability"): enabled-path
    // overhead on the deterministic driver is a few percent; the guard
    // is 10x plus constant slack so loaded CI boxes cannot flake it
    assert!(
        traced < plain * 10.0 + 0.1,
        "traced solve {traced:.4}s vs untraced {plain:.4}s exceeds the overhead bound"
    );
}
