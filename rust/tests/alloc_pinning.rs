//! Allocation pinning for the memory-tier build paths.
//!
//! A counting [`GlobalAlloc`] wrapper around the system allocator
//! tracks live bytes and the high-water mark, so the test can assert
//! *peak allocation* properties the RSS-based bench can only sample:
//!
//!   * `Csr::from_edgelist_owned` sorts the caller's edge buffer in
//!     place — its peak must undercut the borrowing `from_edgelist`
//!     (which pays a full copy of the edges for the dedup sort) by at
//!     least half the copy, pinning the 2×-edge-spike fix;
//!   * `stream_csr_from_bin` never materializes the edge list — its
//!     peak stays under 2× the on-disk edge bytes (the CSR arrays are
//!     ~1× on an erdos web, plus O(n) counters and the read chunk).
//!
//! Everything lives in ONE `#[test]`: the harness runs test fns on
//! concurrent threads, and a second fn would pollute the global
//! counters mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use asyncpr::graph::generators;
use asyncpr::graph::io::{save_edgelist_bin, stream_csr_from_bin, StreamCsrOptions};
use asyncpr::graph::Csr;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let grow = new_size - layout.size();
                let live = LIVE.fetch_add(grow, Ordering::Relaxed) + grow;
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Peak bytes allocated above the live set at entry while running `f`.
fn peak_above_baseline<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let r = f();
    (PEAK.load(Ordering::Relaxed).saturating_sub(base), r)
}

#[test]
fn alloc_memory_tier_build_paths_pin_their_peaks() {
    let n = 40_000usize;
    let m = 320_000usize;
    let el = generators::erdos_renyi(n, m, 7);
    let edge_bytes = el.edges().len() * std::mem::size_of::<(u32, u32)>();

    // ---- owned vs borrowed in-memory build -------------------------
    let el_owned = el.clone(); // clone OUTSIDE the measured regions
    let (peak_borrowed, csr_borrowed) = peak_above_baseline(|| Csr::from_edgelist(&el).unwrap());
    let (peak_owned, csr_owned) =
        peak_above_baseline(|| Csr::from_edgelist_owned(el_owned).unwrap());
    assert_eq!(csr_borrowed, csr_owned, "owned build changed the matrix");
    let saved = peak_borrowed.saturating_sub(peak_owned);
    assert!(
        saved >= edge_bytes / 2,
        "from_edgelist_owned saved only {saved} B of the {edge_bytes} B edge copy \
         (borrowed peak {peak_borrowed}, owned peak {peak_owned})"
    );

    // ---- streaming build from disk ---------------------------------
    let path = std::env::temp_dir().join("asyncpr_alloc_pinning.bin");
    save_edgelist_bin(&el, &path).unwrap();
    let opts = StreamCsrOptions { chunk_bytes: 64 << 10, ..Default::default() };
    let (peak_stream, csr_stream) =
        peak_above_baseline(|| stream_csr_from_bin(&path, &opts).unwrap());
    std::fs::remove_file(&path).unwrap();
    assert_eq!(csr_stream, csr_borrowed, "streamed build changed the matrix");
    assert!(csr_stream.rowptr_is_compact(), "small nnz must narrow");
    assert!(
        peak_stream < 2 * edge_bytes,
        "streaming build peaked at {peak_stream} B, not under 2x the \
         {edge_bytes} B edge list"
    );
    // and the streamed peak must undercut even the owned in-memory
    // route once its input list is charged (list + CSR vs CSR + O(n))
    assert!(
        peak_stream < peak_owned + edge_bytes,
        "streaming ({peak_stream} B) did not beat materialize-then-build \
         ({peak_owned} B + {edge_bytes} B list)"
    );
}
