//! Invariant suite for the certified top-k serving path (seeded random
//! campaigns, same style as resident_proptests.rs — every failure names
//! its trial/round).
//!
//! Invariants covered:
//!   * the certified top-k set equals the exact top-k of a fully
//!     converged power-iteration reference on random churn streams, at
//!     shard counts across 1..8, on the resident AND threaded paths;
//!   * certification is *sound at the moment it fires*: under
//!     `stop_when_topk_certified` the epoch ends at the certificate,
//!     and the set it froze is already the true one;
//!   * the tracker's head after N incremental epochs equals a
//!     from-scratch sort of the final ranks (no drift in the candidate
//!     pools);
//!   * the per-node residual interval `x*_i ∈ [lo_i, hi_i]` holds at
//!     arbitrary interruption points of random churn streams (the
//!     debug-assert cross-check, exercised as a test);
//!   * the `repro stream --topk` driver surface meets its acceptance
//!     shape end to end (columns present, certified heads audit clean,
//!     early-stop mode strictly cheaper).
//!
//! Every test name starts with `topk_`: CI's debug pass skips them and
//! the release pass (with `-C debug-assertions`) runs the whole file.

use asyncpr::asynciter::{run_threaded_push_certified, PushThreadOptions};
use asyncpr::coordinator::experiments::{self, StreamOptions};
use asyncpr::graph::generators;
use asyncpr::pagerank::{top_k_ids, top_k_overlap};
use asyncpr::stream::{
    interval_bounds_sharded, interval_bounds_state, power_method_f64, solve_certified_sharded,
    solve_certified_state, DeltaGraph, PushState, ShardedPush, TopKGoal, TopKTracker,
    UpdateBatch,
};
use asyncpr::util::Rng;

fn web(n: usize, seed: u64) -> DeltaGraph {
    let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
    DeltaGraph::from_edgelist(&el)
}

/// Random churn exercising every mode: inserts (existing and arriving
/// endpoints), deletions, and a forced dangling transition.
fn random_batch(rng: &mut Rng, g: &DeltaGraph) -> UpdateBatch {
    let n0 = g.n();
    let new_nodes = rng.range(0, 3);
    let n1 = n0 + new_nodes;
    let mut b = UpdateBatch { new_nodes, ..Default::default() };
    for _ in 0..rng.range(1, 30) {
        b.insert.push((rng.range(0, n1) as u32, rng.range(0, n1) as u32));
    }
    let mut edges = Vec::new();
    g.for_each_edge(|s, d| edges.push((s, d)));
    if !edges.is_empty() {
        for _ in 0..rng.range(0, 15) {
            b.remove.push(edges[rng.range(0, edges.len())]);
        }
        let (s, _) = edges[rng.range(0, edges.len())];
        for &(es, ed) in &edges {
            if es == s {
                b.remove.push((es, ed));
            }
        }
    }
    b
}

fn ref_topk(xref: &[f64], k: usize) -> Vec<u32> {
    let mut ids = top_k_ids(xref, k);
    ids.sort_unstable();
    ids
}

fn sorted(ids: &[u32]) -> Vec<u32> {
    let mut v = ids.to_vec();
    v.sort_unstable();
    v
}

#[test]
fn topk_certified_set_equals_power_reference_across_shard_counts() {
    let k = 10usize;
    for (trial, shards) in [1usize, 2, 3, 5, 8].into_iter().enumerate() {
        let mut g = web(400 + 60 * trial, 7_000 + trial as u64);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        let mut tracker = TopKTracker::new(TopKGoal { k, order: false });
        let mut rng = Rng::new(7_100 + trial as u64);
        for round in 0..6 {
            if round > 0 {
                let batch = random_batch(&mut rng, &g);
                let delta = g.apply(&batch).unwrap();
                sp.begin_epoch();
                sp.apply_batch(&g, &delta);
            }
            let st = solve_certified_sharded(&mut sp, &g, &mut tracker, 1e-11, u64::MAX, false);
            assert!(st.converged, "trial {trial} round {round}");
            // head after N incremental epochs == from-scratch sort
            let ranks = sp.ranks();
            assert_eq!(
                sorted(&st.cert.head),
                ref_topk(&ranks, k),
                "trial {trial} round {round}: tracker head != fresh sort of final ranks"
            );
            if st.pushes_to_cert.is_some() {
                let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
                assert_eq!(
                    sorted(&st.cert.head),
                    ref_topk(&xref, k),
                    "trial {trial} round {round}: certified set != power top-{k}"
                );
                // the f64 top_k_overlap twin agrees (overlap = 1.0)
                let ov = top_k_overlap(&ranks, &xref, k);
                assert_eq!(ov, 1.0, "trial {trial} round {round}: overlap {ov}");
            }
        }
    }
}

#[test]
fn topk_certification_sound_the_moment_it_fires() {
    // stop_when_topk_certified: the solve ends AT the certificate; the
    // frozen set must already be the truth, with real residual left
    let mut rng = Rng::new(8_000);
    for trial in 0..4u64 {
        let mut g = web(600, 8_100 + trial);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let mut tracker = TopKTracker::new(TopKGoal { k: 12, order: false });
        for round in 0..4 {
            if round > 0 {
                let batch = random_batch(&mut rng, &g);
                let delta = g.apply(&batch).unwrap();
                sp.begin_epoch();
                sp.apply_batch(&g, &delta);
            }
            let st = solve_certified_sharded(&mut sp, &g, &mut tracker, 1e-11, u64::MAX, true);
            let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
            if let Some(at) = st.pushes_to_cert {
                assert_eq!(
                    sorted(&st.cert.head),
                    ref_topk(&xref, 12),
                    "trial {trial} round {round}: set wrong at fire moment ({at} pushes)"
                );
            } else {
                assert!(st.converged, "trial {trial} round {round}: neither cert nor conv");
            }
        }
    }
}

#[test]
fn topk_state_path_matches_reference_and_stops_early() {
    // the single-queue (roundtrip) path: same soundness, and the early
    // stop must beat full convergence on warm epochs
    let mut g = web(900, 8_500);
    let mut inc = PushState::new(g.n(), 0.85);
    let mut tracker = TopKTracker::new(TopKGoal { k: 8, order: true });
    inc.begin_epoch();
    let cold = solve_certified_state(&mut inc, &g, &mut tracker, 1e-11, u64::MAX, false);
    assert!(cold.converged);
    let mut rng = Rng::new(8_600);
    for round in 0..4 {
        let batch = random_batch(&mut rng, &g);
        let delta = g.apply(&batch).unwrap();
        inc.begin_epoch();
        inc.apply_batch(&g, &delta);
        let st = solve_certified_state(&mut inc, &g, &mut tracker, 1e-11, u64::MAX, false);
        assert!(st.converged, "round {round}");
        if let Some(at) = st.pushes_to_cert {
            assert!(
                at <= st.pushes,
                "round {round}: cert point {at} past total {}",
                st.pushes
            );
            let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
            assert_eq!(
                sorted(&st.cert.head),
                ref_topk(&xref, 8),
                "round {round}: ordered-goal certified set wrong"
            );
            if st.cert.order_certified {
                // order certificate: the head must be in exact reference order
                let want = top_k_ids(&xref, 8);
                assert_eq!(st.cert.head, want, "round {round}: certified ORDER wrong");
            }
        }
    }
}

#[test]
fn topk_threaded_resident_path_certifies_soundly() {
    let mut g = web(2_000, 8_800);
    let goal = TopKGoal { k: 16, order: false };
    let mut sp = ShardedPush::new(&g, 0.85, 4);
    let mut tracker = TopKTracker::new(goal);
    let opts = PushThreadOptions { tol: 1e-10, ..Default::default() };
    let mut rng = Rng::new(8_900);
    for round in 0..3 {
        if round > 0 {
            let batch = random_batch(&mut rng, &g);
            let delta = g.apply(&batch).unwrap();
            sp.begin_epoch();
            sp.apply_batch(&g, &delta);
        }
        // tentative monitor stop + exact re-check protocol (owned by
        // the helper), deterministic finish as the backstop
        let out = run_threaded_push_certified(&g, &mut sp, &mut tracker, &opts);
        let mut cert = out.cert;
        if !cert.certified(goal.order) {
            sp.solve(&g, 1e-10, u64::MAX);
            cert = tracker.check_sharded(&mut sp);
        }
        assert!((sp.mass() - 1.0).abs() < 1e-9, "round {round}: mass {}", sp.mass());
        assert!(cert.set_certified, "round {round}: power-law head must certify");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 100_000);
        assert_eq!(
            sorted(&cert.head),
            ref_topk(&xref, 16),
            "round {round}: threaded certified set != power top-16"
        );
    }
}

#[test]
fn topk_interval_bounds_hold_at_random_interruption_points() {
    // the residual-interval invariant under churn: at ARBITRARY push
    // budgets (mid-solve, post-injection, post-arrival) the converged
    // reference must sit inside every node's certified enclosure
    let mut rng = Rng::new(9_000);
    for trial in 0..5u64 {
        let mut g = web(rng.range(80, 400), 9_100 + trial);
        let mut sp = ShardedPush::new(&g, 0.85, 1 + (trial as usize % 4));
        let mut st = PushState::new(g.n(), 0.85);
        st.begin_epoch();
        for round in 0..5 {
            if round > 0 {
                let batch = random_batch(&mut rng, &g);
                let delta = g.apply(&batch).unwrap();
                sp.begin_epoch();
                sp.apply_batch(&g, &delta);
                st.begin_epoch();
                st.apply_batch(&g, &delta);
            }
            let (xref, _) = power_method_f64(&g, 0.85, 1e-14, 200_000);
            for _ in 0..3 {
                let budget = rng.range(0, 400) as u64;
                sp.solve(&g, 1e-12, budget);
                st.solve(&g, 1e-12, budget);
                for (i, &(lo, hi)) in interval_bounds_sharded(&mut sp).iter().enumerate() {
                    assert!(
                        lo - 1e-11 <= xref[i] && xref[i] <= hi + 1e-11,
                        "trial {trial} round {round}: sharded x*[{i}] = {} not in [{lo}, {hi}]",
                        xref[i]
                    );
                }
                for (i, &(lo, hi)) in interval_bounds_state(&mut st).iter().enumerate() {
                    assert!(
                        lo - 1e-11 <= xref[i] && xref[i] <= hi + 1e-11,
                        "trial {trial} round {round}: state x*[{i}] = {} not in [{lo}, {hi}]",
                        xref[i]
                    );
                }
            }
            // settle both before the next batch so epochs stay warm
            sp.solve(&g, 1e-11, u64::MAX);
            st.solve(&g, 1e-11, u64::MAX);
        }
    }
}

#[test]
fn topk_stream_driver_acceptance_resident_and_roundtrip() {
    for resident in [false, true] {
        let opts = StreamOptions {
            epochs: 3,
            topk: Some(16),
            resident,
            threads: if resident { 2 } else { 1 },
            ..Default::default()
        };
        let rep = experiments::stream_epochs("scaled:2000", &opts).unwrap();
        assert_eq!(rep.rows.len(), 4);
        for r in &rep.rows {
            let t = r.topk.as_ref().expect("topk columns present");
            assert_eq!(t.k, 16);
            if t.certified {
                assert_eq!(
                    t.overlap_vs_power, 1.0,
                    "epoch {}: certified head must audit clean",
                    r.epoch
                );
            }
            if let Some(at) = t.pushes_to_cert {
                assert!(at <= r.inc_pushes, "epoch {}: cert after exit", r.epoch);
            }
        }
        // aggregate, not per-epoch: the threaded resident path's push
        // counts wobble with the schedule (same policy as the
        // resident_ suite)
        assert!(
            rep.update_inc_pushes < rep.update_scratch_pushes,
            "resident={resident}: warm {} vs scratch {}",
            rep.update_inc_pushes,
            rep.update_scratch_pushes
        );
    }
}

#[test]
fn topk_stream_driver_early_stop_is_strictly_cheaper() {
    let base = StreamOptions { epochs: 3, topk: Some(16), resident: true, ..Default::default() };
    let full = experiments::stream_epochs("scaled:2000", &base).unwrap();
    let stopped = experiments::stream_epochs(
        "scaled:2000",
        &StreamOptions { topk_stop: true, ..base },
    )
    .unwrap();
    let full_pushes: u64 = full.rows[1..].iter().map(|r| r.inc_pushes).sum();
    let stop_pushes: u64 = stopped.rows[1..].iter().map(|r| r.inc_pushes).sum();
    assert!(
        stop_pushes < full_pushes,
        "early stop {stop_pushes} must beat full convergence {full_pushes}"
    );
    // identical stream => identical certified heads
    for (a, b) in full.rows.iter().zip(&stopped.rows) {
        let (ta, tb) = (a.topk.as_ref().unwrap(), b.topk.as_ref().unwrap());
        if ta.certified && tb.certified {
            assert_eq!(ta.overlap_vs_power, 1.0);
            assert_eq!(tb.overlap_vs_power, 1.0);
        }
    }
}
