//! Property-based tests over coordinator invariants (seeded random
//! campaigns — the offline build carries no proptest crate, so the
//! generators are explicit and every failure prints its trial seed).
//!
//! Invariants covered:
//!   * partitioning: any (n, p) tiles [0, n) exactly; owner_of agrees;
//!   * ELL virtual-row splitting: SpMV identical to CSR for any graph;
//!   * async runs: converge to the power-method ranking for any
//!     topology/jitter/window; mass stays bounded;
//!   * sync runs: iteration-identical to the power method at any p;
//!   * determinism: bit-identical metrics for equal seeds.

use std::sync::Arc;

use asyncpr::asynciter::{BlockOperator, Mode, NativeBlockOp, RunSpec, SimEngine};
use asyncpr::coordinator::Partitioner;
use asyncpr::graph::{generators, Csr, EdgeList, Ell};
use asyncpr::pagerank::{kendall_tau, l1_norm, power_method, PagerankProblem, PowerOptions};
use asyncpr::simnet::{ClusterProfile, Topology};
use asyncpr::stream::{power_method_f64, DeltaGraph, PushState, ShardedPush, UpdateBatch};
use asyncpr::util::Rng;

fn random_edgelist(rng: &mut Rng, n: usize) -> EdgeList {
    let m = rng.range(n, n * 6);
    let mut el = EdgeList::new(n);
    for _ in 0..m {
        el.push(rng.range(0, n) as u32, rng.range(0, n) as u32);
    }
    el
}

fn random_graph(rng: &mut Rng, n: usize) -> Csr {
    Csr::from_edgelist(&random_edgelist(rng, n)).unwrap()
}

#[test]
fn prop_partitioner_tiles_any_n_p() {
    let mut rng = Rng::new(101);
    for trial in 0..300 {
        let p = rng.range(1, 12);
        let n = rng.range(p, p + 5000);
        let part = Partitioner::consecutive(n, p);
        let blocks = part.blocks();
        assert_eq!(blocks.len(), p, "trial {trial}");
        assert_eq!(blocks[0].0, 0);
        assert_eq!(blocks[p - 1].1, n);
        let mut covered = 0usize;
        for (i, &(lo, hi)) in blocks.iter().enumerate() {
            assert!(lo < hi, "trial {trial}: empty block {i}");
            covered += hi - lo;
        }
        assert_eq!(covered, n, "trial {trial}: over/under-cover");
        // spot-check owner_of
        for _ in 0..20 {
            let r = rng.range(0, n);
            let ue = part.owner_of(r);
            let (lo, hi) = blocks[ue];
            assert!((lo..hi).contains(&r), "trial {trial} row {r} ue {ue}");
        }
    }
}

#[test]
fn prop_balanced_partitioner_tiles_and_orders() {
    let mut rng = Rng::new(102);
    for trial in 0..40 {
        let n = rng.range(50, 2000);
        let g = random_graph(&mut rng, n);
        let p = rng.range(1, 9.min(n));
        let part = Partitioner::balanced_nnz(&g, p);
        let blocks = part.blocks();
        assert_eq!(blocks.len(), p, "trial {trial}");
        assert_eq!(blocks[0].0, 0);
        assert_eq!(blocks[p - 1].1, n);
        for w in blocks.windows(2) {
            assert_eq!(w[0].1, w[1].0, "trial {trial}: gap");
        }
    }
}

#[test]
fn prop_ell_spmv_equals_csr_any_width() {
    let mut rng = Rng::new(103);
    for trial in 0..60 {
        let n = rng.range(10, 400);
        let g = random_graph(&mut rng, n);
        let width = rng.range(1, 9);
        let ell = Ell::from_csr(&g, width);
        let x: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let mut y1 = vec![0.0f32; n];
        let mut y2 = vec![0.0f32; n];
        g.spmv(&x, &mut y1);
        ell.spmv(&x, &mut y2);
        for (i, (a, b)) in y1.iter().zip(&y2).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "trial {trial} width {width} row {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_async_converges_any_topology_and_window() {
    let mut rng = Rng::new(104);
    for trial in 0..12 {
        let n = rng.range(400, 1200);
        let el = generators::power_law_web(&generators::WebParams::scaled(n), trial);
        let problem = Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85));
        let p = rng.range(2, 6);
        let topo =
            [Topology::Clique, Topology::Star, Topology::BinaryTree][rng.range(0, 3)];
        let window = if rng.chance(0.5) { None } else { Some(rng.f64() * 5.0 + 0.1) };
        let mut profile = ClusterProfile::test_profile(p).with_topology(topo);
        profile.cancel_window = window;
        // random mild heterogeneity
        for node in profile.nodes.iter_mut() {
            node.slowdown = 1.0 + rng.f64();
        }
        let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(NativeBlockOp::new(problem.clone(), lo, hi))
                    as Box<dyn BlockOperator>
            })
            .collect();
        let spec = RunSpec { seed: trial * 7 + 1, ..RunSpec::paper_table1(Mode::Asynchronous) };
        let m = SimEngine::new(&profile, &problem).run(&mut ops, &spec);

        // mass bounded (the stochastic iteration cannot blow up)
        let mass = l1_norm(&m.x);
        assert!(
            (0.5..2.0).contains(&(mass as f64)),
            "trial {trial} ({topo:?}, w={window:?}): mass {mass}"
        );
        // ranking agrees with the reference
        let pm = power_method(
            &problem,
            &PowerOptions { tol: 1e-9, max_iters: 5000, record_residuals: false },
        );
        let tau = kendall_tau(&m.x, &pm.x);
        assert!(
            tau > 0.99,
            "trial {trial} ({topo:?}, p={p}, w={window:?}): tau {tau}"
        );
    }
}

#[test]
fn prop_sync_equals_power_method_any_p() {
    let mut rng = Rng::new(105);
    for trial in 0..8 {
        let n = rng.range(300, 900);
        let el = generators::power_law_web(&generators::WebParams::scaled(n), trial + 50);
        let problem = Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85));
        let p = rng.range(1, 7);
        let profile = ClusterProfile::test_profile(p);
        let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(NativeBlockOp::new(problem.clone(), lo, hi))
                    as Box<dyn BlockOperator>
            })
            .collect();
        let m = SimEngine::new(&profile, &problem)
            .run(&mut ops, &RunSpec::paper_table1(Mode::Synchronous));
        let pm = power_method(&problem, &PowerOptions::default());
        assert_eq!(
            m.iters[0], pm.iters as u64,
            "trial {trial} p={p}: BSP must be iteration-identical to the power method"
        );
        for (i, (a, b)) in m.x.iter().zip(&pm.x).enumerate() {
            assert!((a - b).abs() < 1e-6, "trial {trial} p={p} row {i}");
        }
    }
}

fn l1_64(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

#[test]
fn prop_sharded_push_matches_single_shard_and_power() {
    // the sharded engine is the same fixed point at every shard count:
    // for random graphs and shard counts 1..8, final ranks match the
    // single-queue PushState AND the f64 power method within the
    // tolerance-implied bound, and the conserved mass stays 1 to 1e-9
    let mut rng = Rng::new(107);
    let tol = 1e-11;
    for trial in 0..6 {
        let n = rng.range(100, 900);
        let el = random_edgelist(&mut rng, n);
        let g = DeltaGraph::from_edgelist(&el);

        let mut single = PushState::new(n, 0.85);
        single.begin_epoch();
        let st = single.solve(&g, tol, u64::MAX);
        assert!(st.converged, "trial {trial}");
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 100_000);

        for shards in 1..=8usize {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            let sst = sp.solve(&g, tol, u64::MAX);
            assert!(sst.converged, "trial {trial} shards {shards}");
            let mass = sp.mass();
            assert!(
                (mass - 1.0).abs() < 1e-9,
                "trial {trial} shards {shards}: mass {mass}"
            );
            let x = sp.ranks();
            let d = l1_64(&x, single.ranks());
            assert!(
                d < 1e-9,
                "trial {trial} shards {shards}: sharded vs single-shard L1 {d}"
            );
            let dp = l1_64(&x, &xref);
            assert!(
                dp < 1e-9,
                "trial {trial} shards {shards}: sharded vs power L1 {dp}"
            );
        }
    }
}

#[test]
fn prop_sharded_epochs_conserve_mass() {
    // warm-start epochs through scatter -> sharded solve -> gather:
    // total mass (ranks + residual) is conserved after every epoch,
    // and the gathered state keeps matching a from-scratch solve
    let mut rng = Rng::new(108);
    let tol = 1e-11;
    for trial in 0..4 {
        let n = rng.range(80, 400);
        let el = random_edgelist(&mut rng, n);
        let mut g = DeltaGraph::from_edgelist(&el);
        let mut inc = PushState::new(g.n(), 0.85);
        inc.begin_epoch();
        inc.solve(&g, tol, u64::MAX);
        for round in 0..4 {
            let n0 = g.n();
            let new_nodes = rng.range(0, 3);
            let mut batch = UpdateBatch { new_nodes, ..Default::default() };
            for _ in 0..rng.range(1, 25) {
                batch.insert.push((
                    rng.range(0, n0 + new_nodes) as u32,
                    rng.range(0, n0) as u32,
                ));
            }
            let mut edges = Vec::new();
            g.for_each_edge(|s, d| edges.push((s, d)));
            if !edges.is_empty() {
                for _ in 0..rng.range(0, 15) {
                    batch.remove.push(edges[rng.range(0, edges.len())]);
                }
            }
            let delta = g.apply(&batch).unwrap();
            inc.begin_epoch();
            inc.apply_batch(&g, &delta);

            let shards = rng.range(2, 7);
            let mut sp = ShardedPush::from_state(&inc, &g, shards);
            let mass_in = sp.mass();
            assert!(
                (mass_in - 1.0).abs() < 1e-9,
                "trial {trial} round {round}: scatter mass {mass_in}"
            );
            let sst = sp.solve(&g, tol, u64::MAX);
            assert!(sst.converged, "trial {trial} round {round}");
            let mass_out = sp.mass();
            assert!(
                (mass_out - 1.0).abs() < 1e-9,
                "trial {trial} round {round}: post-solve mass {mass_out}"
            );
            sp.gather_into(&mut inc);

            let mut cold = PushState::new(g.n(), 0.85);
            cold.begin_epoch();
            cold.solve(&g, tol, u64::MAX);
            let d = l1_64(inc.ranks(), cold.ranks());
            assert!(
                d < 1e-8,
                "trial {trial} round {round}: sharded warm vs cold {d}"
            );
        }
    }
}

#[test]
fn prop_determinism_across_everything() {
    let mut rng = Rng::new(106);
    for trial in 0..6 {
        let n = rng.range(300, 700);
        let el = generators::power_law_web(&generators::WebParams::scaled(n), trial + 90);
        let problem = Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85));
        let p = rng.range(2, 5);
        let seed = rng.next_u64();
        let mode = if rng.chance(0.5) { Mode::Asynchronous } else { Mode::Synchronous };
        let run = || {
            let profile = ClusterProfile::test_profile(p);
            let mut ops: Vec<Box<dyn BlockOperator>> =
                Partitioner::consecutive(problem.n(), p)
                    .blocks()
                    .into_iter()
                    .map(|(lo, hi)| {
                        Box::new(NativeBlockOp::new(problem.clone(), lo, hi))
                            as Box<dyn BlockOperator>
                    })
                    .collect();
            let spec = RunSpec { seed, ..RunSpec::paper_table1(mode) };
            SimEngine::new(&profile, &problem).run(&mut ops, &spec)
        };
        let a = run();
        let b = run();
        assert_eq!(a.iters, b.iters, "trial {trial}");
        assert_eq!(a.x, b.x, "trial {trial}");
        assert_eq!(a.imports, b.imports, "trial {trial}");
        assert_eq!(a.total_time, b.total_time, "trial {trial}");
    }
}
